//! `armincut report TRACE.jsonl` — per-sweep phase breakdown.
//!
//! Parses the compact JSONL event log written next to every Chrome
//! trace (`solve --trace PATH`) and prints, per sweep and per process,
//! how the wall time split across discharge / fuse / sync / disk, plus
//! the idle remainder against the sweep's framing span. The parser is
//! deliberately tiny: the log is our own flat single-line format
//! ([`super::chrome::MergedTrace::jsonl`]), so field extraction is
//! plain string scanning, not a JSON engine.

use super::{EventName, Phase};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Phase columns of the table, in print order.
const COLUMNS: [Phase; 4] = [Phase::Discharge, Phase::Fuse, Phase::Sync, Phase::Disk];

/// Extract the integer value of `"key":` from a flat JSONL line.
/// Returns `None` when the key is absent or non-numeric.
pub fn field_i64(line: &str, key: &str) -> Option<i64> {
    let needle = format!("\"{key}\":");
    let at = line.find(&needle)? + needle.len();
    let rest = line[at..].trim_start();
    let end = rest
        .find(|c: char| !(c.is_ascii_digit() || c == '-'))
        .unwrap_or(rest.len());
    rest[..end].parse().ok()
}

/// Extract the string value of `"key":"…"` from a flat JSONL line.
pub fn field_str<'a>(line: &'a str, key: &str) -> Option<&'a str> {
    let needle = format!("\"{key}\":\"");
    let at = line.find(&needle)? + needle.len();
    let end = line[at..].find('"')?;
    Some(&line[at..at + end])
}

#[derive(Debug, Default, Clone, Copy)]
struct Row {
    /// Busy microseconds per [`COLUMNS`] entry.
    busy: [u64; 4],
    /// The process's own sweep framing span, when it recorded one.
    sweep_span: u64,
}

/// Parse JSONL source into per-(sweep, pid) rows plus the dropped
/// count. Errors on input that holds no parseable event lines.
fn parse(src: &str) -> Result<(BTreeMap<(u32, u32), Row>, u64), String> {
    let mut rows: BTreeMap<(u32, u32), Row> = BTreeMap::new();
    let mut dropped = 0u64;
    let mut parsed = 0u64;
    for line in src.lines() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if line.contains("\"meta\":") {
            dropped += field_i64(line, "dropped").unwrap_or(0).max(0) as u64;
            continue;
        }
        let (Some(pid), Some(name)) = (field_i64(line, "pid"), field_str(line, "name")) else {
            continue;
        };
        let Some(name) = EventName::parse(name) else {
            continue;
        };
        parsed += 1;
        let sweep = field_i64(line, "sweep").unwrap_or(-1);
        if sweep < 0 {
            continue; // not attributable to a sweep (setup, shutdown)
        }
        let dur = field_i64(line, "dur_us").unwrap_or(0).max(0) as u64;
        let row = rows.entry((sweep as u32, pid.max(0) as u32)).or_default();
        if name == EventName::Sweep {
            row.sweep_span += dur;
        } else if let Some(col) = COLUMNS.iter().position(|p| *p == name.phase()) {
            row.busy[col] += dur;
        }
    }
    if parsed == 0 {
        return Err("no trace events found (is this the .jsonl event log?)".into());
    }
    Ok((rows, dropped))
}

/// The longest sweep framing span any process recorded, per sweep — a
/// process without its own span (workers) is framed by this.
fn frames(rows: &BTreeMap<(u32, u32), Row>) -> BTreeMap<u32, u64> {
    let mut frame: BTreeMap<u32, u64> = BTreeMap::new();
    for ((sweep, _), row) in rows {
        let f = frame.entry(*sweep).or_default();
        *f = (*f).max(row.sweep_span);
    }
    frame
}

/// Render the per-sweep phase table from JSONL source. Errors on input
/// that holds no parseable event lines.
pub fn render(src: &str) -> Result<String, String> {
    let (rows, dropped) = parse(src)?;
    let frame = frames(&rows);

    let mut out = String::new();
    let _ = writeln!(out, "per-sweep phase breakdown (milliseconds)");
    let _ = writeln!(
        out,
        "{:>6} {:>9} {:>11} {:>11} {:>11} {:>11} {:>11} {:>11}",
        "sweep", "proc", "discharge", "fuse", "sync", "disk", "idle", "total"
    );
    let mut totals = [0u64; 4];
    for ((sweep, pid), row) in &rows {
        let total = if row.sweep_span > 0 {
            row.sweep_span
        } else {
            frame.get(sweep).copied().unwrap_or(0)
        };
        let busy: u64 = row.busy.iter().sum();
        let idle = total.saturating_sub(busy);
        let proc = if *pid == 0 { "master".to_string() } else { format!("w{}", pid - 1) };
        let _ = writeln!(
            out,
            "{:>6} {:>9} {:>11} {:>11} {:>11} {:>11} {:>11} {:>11}",
            sweep,
            proc,
            ms(row.busy[0]),
            ms(row.busy[1]),
            ms(row.busy[2]),
            ms(row.busy[3]),
            ms(idle),
            ms(total),
        );
        for (t, b) in totals.iter_mut().zip(row.busy.iter()) {
            *t += b;
        }
    }
    let _ = writeln!(
        out,
        "{:>6} {:>9} {:>11} {:>11} {:>11} {:>11}",
        "all",
        "busy",
        ms(totals[0]),
        ms(totals[1]),
        ms(totals[2]),
        ms(totals[3]),
    );
    if dropped > 0 {
        let _ = writeln!(out, "note: {dropped} event(s) dropped at the bounded trace buffer");
    }
    Ok(out)
}

/// Render the top-`n` slowest sweeps (by framing span), each with its
/// phase split summed across processes and the process whose busy time
/// bounded the barrier — the straggler a load-balance fix should chase.
pub fn render_slowest(src: &str, n: usize) -> Result<String, String> {
    let (rows, dropped) = parse(src)?;
    let frame = frames(&rows);

    // per-sweep: phase totals and the busiest process (workers first:
    // the master's busy time never extends a barrier it is waiting on)
    let mut busy: BTreeMap<u32, [u64; 4]> = BTreeMap::new();
    let mut bound: BTreeMap<u32, (u32, u64)> = BTreeMap::new();
    for ((sweep, pid), row) in &rows {
        let b = busy.entry(*sweep).or_default();
        for (t, v) in b.iter_mut().zip(row.busy.iter()) {
            *t += v;
        }
        let row_busy: u64 = row.busy.iter().sum();
        let entry = bound.entry(*sweep).or_insert((*pid, row_busy));
        let beats = match (entry.0, *pid) {
            (0, p) if p > 0 => true, // any worker over the master
            (e, p) if (e > 0) == (p > 0) => row_busy > entry.1,
            _ => false,
        };
        if beats {
            *entry = (*pid, row_busy);
        }
    }

    let mut ranked: Vec<(u32, u64)> = frame.iter().map(|(s, f)| (*s, *f)).collect();
    ranked.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
    ranked.truncate(n);

    let mut out = String::new();
    let _ = writeln!(out, "{} slowest sweeps by wall span (milliseconds)", ranked.len());
    let _ = writeln!(
        out,
        "{:>4} {:>6} {:>11} {:>11} {:>11} {:>11} {:>11} {:>16}",
        "rank", "sweep", "total", "discharge", "fuse", "sync", "disk", "bounded-by"
    );
    for (rank, (sweep, total)) in ranked.iter().enumerate() {
        let b = busy.get(sweep).copied().unwrap_or_default();
        let (pid, pid_busy) = bound.get(sweep).copied().unwrap_or((0, 0));
        let proc = if pid == 0 { "master".to_string() } else { format!("w{}", pid - 1) };
        let _ = writeln!(
            out,
            "{:>4} {:>6} {:>11} {:>11} {:>11} {:>11} {:>11} {:>16}",
            rank + 1,
            sweep,
            ms(*total),
            ms(b[0]),
            ms(b[1]),
            ms(b[2]),
            ms(b[3]),
            format!("{proc} ({})", ms(pid_busy)),
        );
    }
    if dropped > 0 {
        let _ = writeln!(out, "note: {dropped} event(s) dropped at the bounded trace buffer");
    }
    Ok(out)
}

fn ms(us: u64) -> String {
    format!("{:.3}", us as f64 / 1000.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::chrome::{worker_pid, MergedTrace, MASTER_PID};
    use crate::trace::{TraceEvent, NONE};

    fn ev(name: EventName, ts: u64, dur: u64, sweep: u32, region: u32) -> TraceEvent {
        TraceEvent { name, ts_us: ts, dur_us: dur, sweep, region, detail: 0 }
    }

    fn sample() -> String {
        let mut m = MergedTrace::new();
        m.add_remote(
            MASTER_PID,
            0,
            &[
                ev(EventName::Sweep, 0, 10_000, 0, NONE),
                ev(EventName::SyncWait, 100, 4_000, 0, NONE),
                ev(EventName::FuseBarrier, 4_200, 1_000, 0, NONE),
            ],
            0,
        );
        m.add_remote(
            worker_pid(0),
            50,
            &[
                ev(EventName::Discharge, 200, 6_000, 0, 1),
                ev(EventName::PageRead, 6_300, 500, 0, 1),
            ],
            2,
        );
        m.jsonl()
    }

    #[test]
    fn field_extraction_handles_ints_strings_and_absence() {
        let line = "{\"pid\":3,\"name\":\"sweep\",\"sweep\":-1,\"dur_us\":42}";
        assert_eq!(field_i64(line, "pid"), Some(3));
        assert_eq!(field_i64(line, "sweep"), Some(-1));
        assert_eq!(field_i64(line, "dur_us"), Some(42));
        assert_eq!(field_i64(line, "missing"), None);
        assert_eq!(field_str(line, "name"), Some("sweep"));
        assert_eq!(field_str(line, "pid"), None);
    }

    #[test]
    fn table_rolls_phases_up_per_sweep_and_process() {
        let table = render(&sample()).unwrap();
        assert!(table.contains("per-sweep phase breakdown"));
        // master row: 4 ms sync, 1 ms fuse, 5 ms idle of its 10 ms span
        assert!(table.contains("master"), "{table}");
        assert!(table.contains("4.000"), "sync column: {table}");
        assert!(table.contains("1.000"), "fuse column: {table}");
        // worker row: 6 ms discharge, 0.5 ms disk, framed by the
        // master's 10 ms sweep span → 3.5 ms idle
        assert!(table.contains("w0"), "{table}");
        assert!(table.contains("6.000"), "discharge column: {table}");
        assert!(table.contains("0.500"), "disk column: {table}");
        assert!(table.contains("3.500"), "idle fills to the frame: {table}");
        assert!(table.contains("2 event(s) dropped"), "{table}");
    }

    #[test]
    fn events_outside_any_sweep_are_skipped_not_fatal() {
        let mut m = MergedTrace::new();
        m.add_remote(MASTER_PID, 0, &[ev(EventName::Checkpoint, 0, 100, NONE, NONE)], 0);
        m.add_remote(MASTER_PID, 0, &[ev(EventName::Sweep, 0, 100, 0, NONE)], 0);
        let table = render(&m.jsonl()).unwrap();
        assert!(table.contains("master"));
    }

    #[test]
    fn empty_or_foreign_input_is_a_typed_error() {
        assert!(render("").is_err());
        assert!(render("{\"meta\":\"armincut-trace\",\"dropped\":0}\n").is_err());
        assert!(render("not json at all\n").is_err());
        assert!(render_slowest("", 3).is_err());
    }

    fn two_sweep_sample() -> String {
        let mut m = MergedTrace::new();
        m.add_remote(
            MASTER_PID,
            0,
            &[
                ev(EventName::Sweep, 0, 10_000, 0, NONE),
                ev(EventName::SyncWait, 100, 9_000, 0, NONE),
                ev(EventName::Sweep, 10_000, 30_000, 1, NONE),
                ev(EventName::SyncWait, 10_100, 25_000, 1, NONE),
            ],
            0,
        );
        m.add_remote(
            worker_pid(0),
            50,
            &[
                ev(EventName::Discharge, 200, 8_000, 0, 1),
                ev(EventName::Discharge, 10_200, 4_000, 1, 1),
            ],
            0,
        );
        m.add_remote(
            worker_pid(1),
            60,
            &[
                ev(EventName::Discharge, 300, 2_000, 0, 2),
                ev(EventName::Discharge, 10_300, 27_000, 1, 2),
            ],
            0,
        );
        m.jsonl()
    }

    #[test]
    fn slowest_ranks_sweeps_and_names_the_bounding_worker() {
        let out = render_slowest(&two_sweep_sample(), 1).unwrap();
        assert!(out.contains("1 slowest sweeps"), "{out}");
        // sweep 1 (30 ms frame) outranks sweep 0 (10 ms); worker 1's
        // 27 ms discharge bounded it, despite the master's 25 ms sync
        let rank1 = out.lines().find(|l| l.contains("w1 (")).unwrap();
        assert!(rank1.trim_start().starts_with("1 "), "rank column: {out}");
        assert!(rank1.contains("30.000"), "total column: {out}");
        assert!(rank1.contains("w1 (27.000)"), "bounding worker: {out}");
        assert!(!out.contains("w0 ("), "rank cut at n=1: {out}");
    }

    #[test]
    fn slowest_caps_at_available_sweeps_and_sums_phases() {
        let out = render_slowest(&two_sweep_sample(), 10).unwrap();
        assert!(out.contains("2 slowest sweeps"), "{out}");
        // sweep 0 lands at rank 2; w0's 8 ms discharge bounds it
        let rank2 = out.lines().find(|l| l.contains("w0 (")).unwrap();
        assert!(rank2.trim_start().starts_with("2 "), "rank column: {out}");
        assert!(rank2.contains("10.000"), "total column: {out}");
        assert!(rank2.contains("w0 (8.000)"), "bounding worker: {out}");
    }
}
