//! `armincut report TRACE.jsonl` — per-sweep phase breakdown.
//!
//! Parses the compact JSONL event log written next to every Chrome
//! trace (`solve --trace PATH`) and prints, per sweep and per process,
//! how the wall time split across discharge / fuse / sync / disk, plus
//! the idle remainder against the sweep's framing span. The parser is
//! deliberately tiny: the log is our own flat single-line format
//! ([`super::chrome::MergedTrace::jsonl`]), so field extraction is
//! plain string scanning, not a JSON engine.

use super::{EventName, Phase};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Phase columns of the table, in print order.
const COLUMNS: [Phase; 4] = [Phase::Discharge, Phase::Fuse, Phase::Sync, Phase::Disk];

/// Extract the integer value of `"key":` from a flat JSONL line.
/// Returns `None` when the key is absent or non-numeric.
pub fn field_i64(line: &str, key: &str) -> Option<i64> {
    let needle = format!("\"{key}\":");
    let at = line.find(&needle)? + needle.len();
    let rest = line[at..].trim_start();
    let end = rest
        .find(|c: char| !(c.is_ascii_digit() || c == '-'))
        .unwrap_or(rest.len());
    rest[..end].parse().ok()
}

/// Extract the string value of `"key":"…"` from a flat JSONL line.
pub fn field_str<'a>(line: &'a str, key: &str) -> Option<&'a str> {
    let needle = format!("\"{key}\":\"");
    let at = line.find(&needle)? + needle.len();
    let end = line[at..].find('"')?;
    Some(&line[at..at + end])
}

#[derive(Debug, Default, Clone, Copy)]
struct Row {
    /// Busy microseconds per [`COLUMNS`] entry.
    busy: [u64; 4],
    /// The process's own sweep framing span, when it recorded one.
    sweep_span: u64,
}

/// Render the per-sweep phase table from JSONL source. Errors on input
/// that holds no parseable event lines.
pub fn render(src: &str) -> Result<String, String> {
    let mut rows: BTreeMap<(u32, u32), Row> = BTreeMap::new();
    let mut dropped = 0u64;
    let mut parsed = 0u64;
    for line in src.lines() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if line.contains("\"meta\":") {
            dropped += field_i64(line, "dropped").unwrap_or(0).max(0) as u64;
            continue;
        }
        let (Some(pid), Some(name)) = (field_i64(line, "pid"), field_str(line, "name")) else {
            continue;
        };
        let Some(name) = EventName::parse(name) else {
            continue;
        };
        parsed += 1;
        let sweep = field_i64(line, "sweep").unwrap_or(-1);
        if sweep < 0 {
            continue; // not attributable to a sweep (setup, shutdown)
        }
        let dur = field_i64(line, "dur_us").unwrap_or(0).max(0) as u64;
        let row = rows.entry((sweep as u32, pid.max(0) as u32)).or_default();
        if name == EventName::Sweep {
            row.sweep_span += dur;
        } else if let Some(col) = COLUMNS.iter().position(|p| *p == name.phase()) {
            row.busy[col] += dur;
        }
    }
    if parsed == 0 {
        return Err("no trace events found (is this the .jsonl event log?)".into());
    }

    // a process without its own framing span (workers) is framed by
    // the longest sweep span any process recorded for that sweep
    let mut frame: BTreeMap<u32, u64> = BTreeMap::new();
    for ((sweep, _), row) in &rows {
        let f = frame.entry(*sweep).or_default();
        *f = (*f).max(row.sweep_span);
    }

    let mut out = String::new();
    let _ = writeln!(out, "per-sweep phase breakdown (milliseconds)");
    let _ = writeln!(
        out,
        "{:>6} {:>9} {:>11} {:>11} {:>11} {:>11} {:>11} {:>11}",
        "sweep", "proc", "discharge", "fuse", "sync", "disk", "idle", "total"
    );
    let mut totals = [0u64; 4];
    for ((sweep, pid), row) in &rows {
        let total = if row.sweep_span > 0 {
            row.sweep_span
        } else {
            frame.get(sweep).copied().unwrap_or(0)
        };
        let busy: u64 = row.busy.iter().sum();
        let idle = total.saturating_sub(busy);
        let proc = if *pid == 0 { "master".to_string() } else { format!("w{}", pid - 1) };
        let _ = writeln!(
            out,
            "{:>6} {:>9} {:>11} {:>11} {:>11} {:>11} {:>11} {:>11}",
            sweep,
            proc,
            ms(row.busy[0]),
            ms(row.busy[1]),
            ms(row.busy[2]),
            ms(row.busy[3]),
            ms(idle),
            ms(total),
        );
        for (t, b) in totals.iter_mut().zip(row.busy.iter()) {
            *t += b;
        }
    }
    let _ = writeln!(
        out,
        "{:>6} {:>9} {:>11} {:>11} {:>11} {:>11}",
        "all",
        "busy",
        ms(totals[0]),
        ms(totals[1]),
        ms(totals[2]),
        ms(totals[3]),
    );
    if dropped > 0 {
        let _ = writeln!(out, "note: {dropped} event(s) dropped at the bounded trace buffer");
    }
    Ok(out)
}

fn ms(us: u64) -> String {
    format!("{:.3}", us as f64 / 1000.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::chrome::{worker_pid, MergedTrace, MASTER_PID};
    use crate::trace::{TraceEvent, NONE};

    fn ev(name: EventName, ts: u64, dur: u64, sweep: u32, region: u32) -> TraceEvent {
        TraceEvent { name, ts_us: ts, dur_us: dur, sweep, region, detail: 0 }
    }

    fn sample() -> String {
        let mut m = MergedTrace::new();
        m.add_remote(
            MASTER_PID,
            0,
            &[
                ev(EventName::Sweep, 0, 10_000, 0, NONE),
                ev(EventName::SyncWait, 100, 4_000, 0, NONE),
                ev(EventName::FuseBarrier, 4_200, 1_000, 0, NONE),
            ],
            0,
        );
        m.add_remote(
            worker_pid(0),
            50,
            &[
                ev(EventName::Discharge, 200, 6_000, 0, 1),
                ev(EventName::PageRead, 6_300, 500, 0, 1),
            ],
            2,
        );
        m.jsonl()
    }

    #[test]
    fn field_extraction_handles_ints_strings_and_absence() {
        let line = "{\"pid\":3,\"name\":\"sweep\",\"sweep\":-1,\"dur_us\":42}";
        assert_eq!(field_i64(line, "pid"), Some(3));
        assert_eq!(field_i64(line, "sweep"), Some(-1));
        assert_eq!(field_i64(line, "dur_us"), Some(42));
        assert_eq!(field_i64(line, "missing"), None);
        assert_eq!(field_str(line, "name"), Some("sweep"));
        assert_eq!(field_str(line, "pid"), None);
    }

    #[test]
    fn table_rolls_phases_up_per_sweep_and_process() {
        let table = render(&sample()).unwrap();
        assert!(table.contains("per-sweep phase breakdown"));
        // master row: 4 ms sync, 1 ms fuse, 5 ms idle of its 10 ms span
        assert!(table.contains("master"), "{table}");
        assert!(table.contains("4.000"), "sync column: {table}");
        assert!(table.contains("1.000"), "fuse column: {table}");
        // worker row: 6 ms discharge, 0.5 ms disk, framed by the
        // master's 10 ms sweep span → 3.5 ms idle
        assert!(table.contains("w0"), "{table}");
        assert!(table.contains("6.000"), "discharge column: {table}");
        assert!(table.contains("0.500"), "disk column: {table}");
        assert!(table.contains("3.500"), "idle fills to the frame: {table}");
        assert!(table.contains("2 event(s) dropped"), "{table}");
    }

    #[test]
    fn events_outside_any_sweep_are_skipped_not_fatal() {
        let mut m = MergedTrace::new();
        m.add_remote(MASTER_PID, 0, &[ev(EventName::Checkpoint, 0, 100, NONE, NONE)], 0);
        m.add_remote(MASTER_PID, 0, &[ev(EventName::Sweep, 0, 100, 0, NONE)], 0);
        let table = render(&m.jsonl()).unwrap();
        assert!(table.contains("master"));
    }

    #[test]
    fn empty_or_foreign_input_is_a_typed_error() {
        assert!(render("").is_err());
        assert!(render("{\"meta\":\"armincut-trace\",\"dropped\":0}\n").is_err());
        assert!(render("not json at all\n").is_err());
    }
}
