//! Merging per-process event streams and rendering them: Chrome
//! trace-event JSON (loadable in `chrome://tracing` or
//! <https://ui.perfetto.dev>) plus the compact JSONL event log that
//! `armincut report` consumes.
//!
//! Each contributing process is one Chrome *pid*: the master (or a
//! local coordinator) is pid 0, worker `w` is pid `w + 1`. Worker
//! timestamps are re-based onto the master's axis with the clock
//! offset estimated at the `Hello` handshake (master receipt time
//! minus the worker's stamped clock — loopback latency is inside the
//! estimate, which is fine for timeline rendering), clamped so every
//! shipped stream stays monotone per process.

use super::{EventName, TraceEvent, Tracer, NONE};
use std::fmt::Write as _;
use std::path::{Path, PathBuf};

/// Pid of the master / local coordinator in the merged timeline.
pub const MASTER_PID: u32 = 0;

/// Pid of distributed worker `w`.
pub fn worker_pid(worker: u32) -> u32 {
    worker.saturating_add(1)
}

/// One merged multi-process timeline, on the master's clock.
#[derive(Debug, Default)]
pub struct MergedTrace {
    /// `(pid, event)` pairs; per-pid subsequences are monotone in
    /// `ts_us`.
    pub events: Vec<(u32, TraceEvent)>,
    /// Total events dropped across all contributing buffers.
    pub dropped: u64,
}

impl MergedTrace {
    /// An empty timeline.
    pub fn new() -> MergedTrace {
        MergedTrace::default()
    }

    /// Drain a local tracer (already on the reference clock) into the
    /// timeline as `pid`.
    pub fn add_local(&mut self, pid: u32, tracer: &mut Tracer) {
        let (events, dropped) = tracer.take_batch();
        self.dropped += dropped;
        self.events.extend(events.into_iter().map(|e| (pid, e)));
    }

    /// Merge one shipped worker batch: shift every timestamp by
    /// `offset_us` (the handshake estimate), clamping so the batch
    /// stays monotone even when the shift saturates at zero.
    pub fn add_remote(
        &mut self,
        pid: u32,
        offset_us: i64,
        events: &[TraceEvent],
        dropped: u64,
    ) {
        self.dropped += dropped;
        let mut floor = self
            .events
            .iter()
            .rev()
            .find(|(p, _)| *p == pid)
            .map_or(0, |(_, e)| e.ts_us);
        for ev in events {
            let shifted = shift_us(ev.ts_us, offset_us).max(floor);
            floor = shifted;
            self.events.push((pid, TraceEvent { ts_us: shifted, ..*ev }));
        }
    }

    /// Pids present, ascending and deduplicated.
    pub fn pids(&self) -> Vec<u32> {
        let mut pids: Vec<u32> = self.events.iter().map(|(p, _)| *p).collect();
        pids.sort_unstable();
        pids.dedup();
        pids
    }

    /// Render the Chrome trace-event JSON document.
    pub fn chrome_json(&self) -> String {
        let mut s = String::new();
        s.push_str("{\"traceEvents\":[\n");
        let mut first = true;
        for pid in self.pids() {
            let label = if pid == MASTER_PID {
                "master".to_string()
            } else {
                format!("worker {}", pid - 1)
            };
            append_sep(&mut s, &mut first);
            let _ = write!(
                s,
                "{{\"name\":\"process_name\",\"ph\":\"M\",\"pid\":{pid},\"tid\":0,\
                 \"args\":{{\"name\":\"{label}\"}}}}"
            );
        }
        for (pid, ev) in &self.events {
            append_sep(&mut s, &mut first);
            // spans get their own row per region so concurrent
            // discharges render side by side; everything else rides
            // the process's row 0
            let tid = if ev.region == NONE { 0 } else { ev.region.saturating_add(1) };
            let ph = if is_span(ev.name) { "X" } else { "i" };
            let _ = write!(
                s,
                "{{\"name\":\"{}\",\"cat\":\"{}\",\"ph\":\"{ph}\",\"pid\":{pid},\
                 \"tid\":{tid},\"ts\":{}",
                ev.name.as_str(),
                ev.name.phase().as_str(),
                ev.ts_us,
            );
            if ph == "X" {
                let _ = write!(s, ",\"dur\":{}", ev.dur_us);
            } else {
                s.push_str(",\"s\":\"t\"");
            }
            let _ = write!(
                s,
                ",\"args\":{{\"sweep\":{},\"region\":{},\"detail\":{}}}}}",
                arg_u32(ev.sweep),
                arg_u32(ev.region),
                ev.detail,
            );
        }
        let _ = write!(
            s,
            "\n],\"displayTimeUnit\":\"ms\",\"otherData\":{{\"dropped_events\":{}}}}}\n",
            self.dropped
        );
        s
    }

    /// Render the compact JSONL log: one meta line, then one flat
    /// object per event (the format [`super::report`] parses).
    pub fn jsonl(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(
            s,
            "{{\"meta\":\"armincut-trace\",\"version\":1,\"events\":{},\"dropped\":{}}}",
            self.events.len(),
            self.dropped
        );
        for (pid, ev) in &self.events {
            let _ = writeln!(
                s,
                "{{\"pid\":{pid},\"name\":\"{}\",\"phase\":\"{}\",\"ts_us\":{},\
                 \"dur_us\":{},\"sweep\":{},\"region\":{},\"detail\":{}}}",
                ev.name.as_str(),
                ev.name.phase().as_str(),
                ev.ts_us,
                ev.dur_us,
                arg_u32(ev.sweep),
                arg_u32(ev.region),
                ev.detail,
            );
        }
        s
    }

    /// Write both renderings: the Chrome JSON at `path` and the JSONL
    /// log beside it (extension replaced with `.jsonl`). Returns the
    /// JSONL path.
    pub fn write(&self, path: &Path) -> std::io::Result<PathBuf> {
        std::fs::write(path, self.chrome_json())?;
        let jsonl_path = path.with_extension("jsonl");
        std::fs::write(&jsonl_path, self.jsonl())?;
        Ok(jsonl_path)
    }
}

/// Whether the vocabulary entry is rendered as a Chrome `X` (complete
/// span) event; everything else is an `i` instant.
fn is_span(name: EventName) -> bool {
    !matches!(
        name,
        EventName::PrefetchHit
            | EventName::PrefetchMiss
            | EventName::WireSend
            | EventName::WireRecv
            | EventName::FailureDetected
            | EventName::BatchReissue
    )
}

/// Apply a signed clock offset to an unsigned timestamp, saturating at
/// the axis ends instead of wrapping.
pub fn shift_us(ts: u64, offset_us: i64) -> u64 {
    if offset_us >= 0 {
        ts.saturating_add(offset_us as u64)
    } else {
        ts.saturating_sub(offset_us.unsigned_abs())
    }
}

fn arg_u32(v: u32) -> i64 {
    if v == NONE {
        -1
    } else {
        v as i64
    }
}

fn append_sep(s: &mut String, first: &mut bool) {
    if *first {
        *first = false;
    } else {
        s.push_str(",\n");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(name: EventName, ts: u64, dur: u64) -> TraceEvent {
        TraceEvent { name, ts_us: ts, dur_us: dur, sweep: 0, region: 2, detail: 7 }
    }

    #[test]
    fn remote_merge_is_monotone_per_pid_for_any_offset() {
        // worker clocks ahead of AND behind the master, including an
        // offset that saturates early timestamps at zero
        for offset in [250i64, 0, -40, -1_000_000] {
            let mut m = MergedTrace::new();
            let batch = [
                ev(EventName::Discharge, 10, 5),
                ev(EventName::Discharge, 30, 5),
                ev(EventName::FuseFold, 90, 1),
            ];
            m.add_remote(worker_pid(0), offset, &batch, 0);
            // a second batch from the same worker starts behind the
            // first one's clamped floor and must not step backwards
            m.add_remote(worker_pid(0), offset, &[ev(EventName::SyncWait, 95, 2)], 0);
            let ts: Vec<u64> = m.events.iter().map(|(_, e)| e.ts_us).collect();
            let mut sorted = ts.clone();
            sorted.sort_unstable();
            assert_eq!(ts, sorted, "offset {offset}: merged stream is monotone");
        }
    }

    #[test]
    fn shift_saturates_instead_of_wrapping() {
        assert_eq!(shift_us(10, -50), 0);
        assert_eq!(shift_us(10, 50), 60);
        assert_eq!(shift_us(u64::MAX - 1, 10), u64::MAX);
    }

    #[test]
    fn chrome_json_names_every_process_and_balances_braces() {
        let mut m = MergedTrace::new();
        let mut t = Tracer::new(8);
        t.instant(EventName::WireSend, 0, 1, 64);
        m.add_local(MASTER_PID, &mut t);
        m.add_remote(worker_pid(0), 5, &[ev(EventName::Discharge, 4, 9)], 3);
        let json = m.chrome_json();
        assert!(json.contains("\"traceEvents\""));
        assert!(json.contains("\"name\":\"master\""));
        assert!(json.contains("\"name\":\"worker 0\""));
        assert!(json.contains("\"ph\":\"X\""), "spans render as complete events");
        assert!(json.contains("\"ph\":\"i\""), "instants render as instant events");
        assert!(json.contains("\"dropped_events\":3"));
        assert_eq!(json.matches('{').count(), json.matches('}').count());
        assert_eq!(json.matches('[').count(), json.matches(']').count());
    }

    #[test]
    fn jsonl_has_one_meta_line_plus_one_line_per_event() {
        let mut m = MergedTrace::new();
        m.add_remote(worker_pid(1), 0, &[ev(EventName::PageRead, 1, 2)], 1);
        let out = m.jsonl();
        let lines: Vec<&str> = out.lines().collect();
        assert_eq!(lines.len(), 2);
        assert!(lines[0].contains("\"meta\":\"armincut-trace\""));
        assert!(lines[0].contains("\"dropped\":1"));
        assert!(lines[1].contains("\"name\":\"page_read\""));
        assert!(lines[1].contains("\"phase\":\"disk\""));
        assert!(lines[1].contains("\"pid\":2"));
    }

    #[test]
    fn none_sentinels_render_as_minus_one() {
        let mut m = MergedTrace::new();
        let e = TraceEvent {
            name: EventName::Sweep,
            ts_us: 0,
            dur_us: 10,
            sweep: 3,
            region: NONE,
            detail: 0,
        };
        m.add_remote(MASTER_PID, 0, &[e], 0);
        assert!(m.jsonl().contains("\"region\":-1"));
        assert!(m.chrome_json().contains("\"region\":-1"));
    }
}
