//! Zero-dependency structured tracing: spans and instant events on a
//! monotonic clock, recorded into a bounded in-memory buffer.
//!
//! The paper argues in *sweeps* — the `2|B|² + 1` bound, the "about 10
//! sweeps in practice" claim — but end-of-run aggregates
//! ([`RunMetrics`](crate::coordinator::metrics::RunMetrics)) cannot
//! show where inside a sweep the time goes. This module gives all
//! three runtimes (sequential, threaded parallel, distributed) one
//! shared recorder:
//!
//! * [`Tracer`] — per-thread/per-process event recorder with
//!   microsecond timestamps relative to its construction instant. The
//!   buffer is **bounded**: capacity is allocated once and overflowing
//!   events are counted in a drop counter instead of growing the
//!   buffer, so tracing can never OOM a 10⁸-vertex run.
//! * [`EventName`] — the closed event vocabulary (sweeps, region
//!   discharges, fusion fold + α-filter barrier, store page I/O and
//!   prefetch hits/misses, wire send/recv, recovery), each mapped to a
//!   [`Phase`] rollup category.
//! * [`chrome`] — merges per-process event streams (worker clocks
//!   re-based via the Hello-handshake offset) and renders Chrome
//!   trace-event JSON (`chrome://tracing` / Perfetto) plus a compact
//!   JSONL event log.
//! * [`report`] — the `armincut report TRACE.jsonl` per-sweep phase
//!   breakdown table.
//!
//! Distributed flow: workers buffer spans locally and ship them as
//! [`Msg::TraceBatch`](crate::dist::proto::Msg) frames piggybacked on
//! every reply at the sweep barrier; the master re-bases them onto its
//! own axis and writes the merged timeline (`solve --trace PATH`).
//!
//! Everything here is advisory instrumentation: a disabled tracer
//! records nothing, and enabling one must not change any solve result
//! (pinned by `tracing_does_not_perturb_the_solve` in the coordinator
//! tests).

use std::time::{Duration, Instant};

pub mod chrome;
pub mod report;

/// Sentinel for events not tied to a sweep or region.
pub const NONE: u32 = u32::MAX;

/// Default bounded-buffer capacity in events (32 B each → ≤ 2 MiB
/// resident per tracer, however long the run).
pub const DEFAULT_CAPACITY: usize = 65_536;

/// Rollup category of an event — the phase columns of
/// `armincut report`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Whole-sweep framing spans.
    Sweep,
    /// Region discharge work (ARD/PRD cores).
    Discharge,
    /// Fusion fold + the α-filter barrier.
    Fuse,
    /// Wire wait / sync-in composition / send-recv accounting.
    Sync,
    /// Store page reads, writes and prefetch outcomes.
    Disk,
    /// Failure detection, restarts, resumes, batch re-issues.
    Recovery,
}

impl Phase {
    /// Stable lower-case label used in the JSONL log and the report.
    pub fn as_str(self) -> &'static str {
        match self {
            Phase::Sweep => "sweep",
            Phase::Discharge => "discharge",
            Phase::Fuse => "fuse",
            Phase::Sync => "sync",
            Phase::Disk => "disk",
            Phase::Recovery => "recovery",
        }
    }
}

/// The closed event vocabulary. Every event carries one of these, so
/// wire encoding is a single byte ([`EventName::code`]) and the
/// taxonomy documented in ARCHITECTURE.md § Observability is
/// enforceable.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventName {
    /// Span: one whole sweep (master / local coordinator).
    Sweep,
    /// Span: one region discharge (`detail` = discharges so far).
    Discharge,
    /// Span: folding one boundary delta into the `FusionRound`.
    FuseFold,
    /// Span: the α-filter barrier (`FusionRound::finish`).
    FuseBarrier,
    /// Span: waiting on the wire / composing sync-in snapshots.
    SyncWait,
    /// Span: a store page read (`detail` = stored bytes if known).
    PageRead,
    /// Span: a store page write-back (`detail` = stored bytes).
    PageWrite,
    /// Instant: a prefetched page was ready when requested.
    PrefetchHit,
    /// Instant: a requested page missed the prefetch pipeline.
    PrefetchMiss,
    /// Instant: one wire frame sent (`detail` = bytes, `region` = the
    /// `Msg` kind discriminant).
    WireSend,
    /// Instant: one wire frame received (same field use as
    /// [`EventName::WireSend`]).
    WireRecv,
    /// Instant: a worker failure was detected (`region` = connection).
    FailureDetected,
    /// Span: respawn/redial + `Resume` handshake of one worker.
    WorkerRestart,
    /// Instant: a composed batch was re-issued after recovery.
    BatchReissue,
    /// Span: one master checkpoint write (`detail` = bytes).
    Checkpoint,
}

/// All vocabulary entries, in wire-code order (used by the exhaustive
/// encode/decode tests).
pub const ALL_EVENT_NAMES: [EventName; 15] = [
    EventName::Sweep,
    EventName::Discharge,
    EventName::FuseFold,
    EventName::FuseBarrier,
    EventName::SyncWait,
    EventName::PageRead,
    EventName::PageWrite,
    EventName::PrefetchHit,
    EventName::PrefetchMiss,
    EventName::WireSend,
    EventName::WireRecv,
    EventName::FailureDetected,
    EventName::WorkerRestart,
    EventName::BatchReissue,
    EventName::Checkpoint,
];

impl EventName {
    /// The rollup phase this event accrues to.
    pub fn phase(self) -> Phase {
        match self {
            EventName::Sweep => Phase::Sweep,
            EventName::Discharge => Phase::Discharge,
            EventName::FuseFold | EventName::FuseBarrier => Phase::Fuse,
            EventName::SyncWait | EventName::WireSend | EventName::WireRecv => Phase::Sync,
            EventName::PageRead
            | EventName::PageWrite
            | EventName::PrefetchHit
            | EventName::PrefetchMiss => Phase::Disk,
            EventName::FailureDetected
            | EventName::WorkerRestart
            | EventName::BatchReissue
            | EventName::Checkpoint => Phase::Recovery,
        }
    }

    /// Stable snake-case name used in both trace outputs.
    pub fn as_str(self) -> &'static str {
        match self {
            EventName::Sweep => "sweep",
            EventName::Discharge => "discharge",
            EventName::FuseFold => "fuse_fold",
            EventName::FuseBarrier => "fuse_barrier",
            EventName::SyncWait => "sync_wait",
            EventName::PageRead => "page_read",
            EventName::PageWrite => "page_write",
            EventName::PrefetchHit => "prefetch_hit",
            EventName::PrefetchMiss => "prefetch_miss",
            EventName::WireSend => "wire_send",
            EventName::WireRecv => "wire_recv",
            EventName::FailureDetected => "failure_detected",
            EventName::WorkerRestart => "worker_restart",
            EventName::BatchReissue => "batch_reissue",
            EventName::Checkpoint => "checkpoint",
        }
    }

    /// Single-byte wire discriminant (stable across releases; the
    /// `TraceBatch` payload depends on it).
    pub fn code(self) -> u8 {
        match self {
            EventName::Sweep => 0,
            EventName::Discharge => 1,
            EventName::FuseFold => 2,
            EventName::FuseBarrier => 3,
            EventName::SyncWait => 4,
            EventName::PageRead => 5,
            EventName::PageWrite => 6,
            EventName::PrefetchHit => 7,
            EventName::PrefetchMiss => 8,
            EventName::WireSend => 9,
            EventName::WireRecv => 10,
            EventName::FailureDetected => 11,
            EventName::WorkerRestart => 12,
            EventName::BatchReissue => 13,
            EventName::Checkpoint => 14,
        }
    }

    /// Inverse of [`EventName::code`]; `None` for foreign bytes (a
    /// corrupt or future frame must not mis-decode).
    pub fn from_code(code: u8) -> Option<EventName> {
        ALL_EVENT_NAMES.get(code as usize).copied()
    }

    /// Inverse of [`EventName::as_str`] (the report parses JSONL).
    pub fn parse(name: &str) -> Option<EventName> {
        ALL_EVENT_NAMES.iter().copied().find(|n| n.as_str() == name)
    }
}

/// One recorded event: a span (`dur_us > 0` possible) or an instant
/// (`dur_us == 0` by construction). Fixed-size and `Copy`, so the
/// bounded buffer holds plain values and the wire encoding is flat.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceEvent {
    /// What happened.
    pub name: EventName,
    /// Microseconds since the recording tracer's epoch.
    pub ts_us: u64,
    /// Span duration in microseconds; `0` for instant events.
    pub dur_us: u64,
    /// Sweep number, or [`NONE`].
    pub sweep: u32,
    /// Region id, connection index, or `Msg` kind — see the
    /// per-variant docs on [`EventName`]; [`NONE`] when unused.
    pub region: u32,
    /// Free counter: bytes moved, discharge count, restart number.
    pub detail: u64,
}

/// Per-process event recorder. See the module docs for the contract;
/// the short version: construction fixes the capacity, recording never
/// allocates past it, and a disabled tracer records nothing while its
/// clock keeps working (workers stamp `Hello` before they know whether
/// the master wants traces).
#[derive(Debug)]
pub struct Tracer {
    epoch: Instant,
    buf: Vec<TraceEvent>,
    capacity: usize,
    dropped: u64,
    enabled: bool,
}

impl Tracer {
    /// An enabled tracer holding at most `capacity` events.
    pub fn new(capacity: usize) -> Tracer {
        let capacity = capacity.max(1);
        Tracer {
            epoch: Instant::now(),
            buf: Vec::with_capacity(capacity),
            capacity,
            dropped: 0,
            enabled: true,
        }
    }

    /// A tracer that records nothing (the default for every solve).
    pub fn disabled() -> Tracer {
        Tracer {
            epoch: Instant::now(),
            buf: Vec::new(),
            capacity: 0,
            dropped: 0,
            enabled: false,
        }
    }

    /// Arm a disabled tracer in place, keeping its epoch — the worker
    /// path: the epoch must predate the `Hello` clock sample, but the
    /// master only asks for traces in the later `AssignShard`.
    pub fn enable(&mut self, capacity: usize) {
        if self.enabled {
            return;
        }
        self.capacity = capacity.max(1);
        self.buf = Vec::with_capacity(self.capacity);
        self.enabled = true;
    }

    /// Whether events are being recorded.
    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    /// Microseconds since the tracer's epoch (works when disabled —
    /// the clock-offset handshake needs it either way).
    pub fn now_us(&self) -> u64 {
        duration_us(self.epoch.elapsed())
    }

    /// Record a span measured externally: `start`/`dur` are the same
    /// `Instant`/`Duration` pair the metrics timers accrue, so trace
    /// span sums and `RunMetrics` rollups agree by construction.
    pub fn span_at(
        &mut self,
        name: EventName,
        start: Instant,
        dur: Duration,
        sweep: u32,
        region: u32,
        detail: u64,
    ) {
        if !self.enabled {
            return;
        }
        let ts_us = duration_us(start.saturating_duration_since(self.epoch));
        self.push(TraceEvent { name, ts_us, dur_us: duration_us(dur), sweep, region, detail });
    }

    /// Record an instant event stamped now.
    pub fn instant(&mut self, name: EventName, sweep: u32, region: u32, detail: u64) {
        if !self.enabled {
            return;
        }
        let ts_us = self.now_us();
        self.push(TraceEvent { name, ts_us, dur_us: 0, sweep, region, detail });
    }

    /// Bounded insert: a full buffer counts the event as dropped
    /// instead of reallocating.
    pub fn push(&mut self, ev: TraceEvent) {
        if !self.enabled {
            return;
        }
        if self.buf.len() >= self.capacity {
            self.dropped += 1;
        } else {
            self.buf.push(ev);
        }
    }

    /// The recorded events, in recording order.
    pub fn events(&self) -> &[TraceEvent] {
        &self.buf
    }

    /// Events dropped on overflow since the last [`Tracer::take_batch`].
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Number of currently buffered events.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    /// Whether the buffer is empty.
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Drain the buffer for shipment (the worker's `TraceBatch`
    /// piggyback): returns the buffered events plus the drop count
    /// accrued since the previous batch, keeping the allocation.
    pub fn take_batch(&mut self) -> (Vec<TraceEvent>, u64) {
        let events: Vec<TraceEvent> = self.buf.drain(..).collect();
        let dropped = self.dropped;
        self.dropped = 0;
        (events, dropped)
    }
}

/// Accumulates per-sweep wall times into the min/mean/max rollup the
/// `RunMetrics` summary tail prints. Fed from the same sweep spans the
/// tracer records (every coordinator calls [`SweepRollup::add`] with
/// the sweep's measured duration whether or not tracing is on).
#[derive(Debug, Clone, Copy, Default)]
pub struct SweepRollup {
    /// Sweeps accumulated.
    pub count: u32,
    /// Shortest sweep wall time.
    pub min: Duration,
    /// Longest sweep wall time.
    pub max: Duration,
    /// Sum over all sweeps (mean = `sum / count`).
    pub sum: Duration,
}

impl SweepRollup {
    /// Fold one sweep's wall time in.
    pub fn add(&mut self, dur: Duration) {
        if self.count == 0 || dur < self.min {
            self.min = dur;
        }
        if dur > self.max {
            self.max = dur;
        }
        self.sum += dur;
        self.count += 1;
    }

    /// Mean sweep wall time (zero before any sweep).
    pub fn mean(&self) -> Duration {
        if self.count == 0 {
            Duration::ZERO
        } else {
            self.sum / self.count
        }
    }
}

/// Whole microseconds of a `Duration`, saturating at `u64::MAX`.
pub fn duration_us(d: Duration) -> u64 {
    u64::try_from(d.as_micros()).unwrap_or(u64::MAX)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vocabulary_codes_roundtrip_and_reject_foreign_bytes() {
        for (i, name) in ALL_EVENT_NAMES.iter().enumerate() {
            assert_eq!(name.code() as usize, i);
            assert_eq!(EventName::from_code(name.code()), Some(*name));
            assert_eq!(EventName::parse(name.as_str()), Some(*name));
        }
        assert_eq!(EventName::from_code(ALL_EVENT_NAMES.len() as u8), None);
        assert_eq!(EventName::from_code(0xFF), None);
        assert_eq!(EventName::parse("no_such_event"), None);
    }

    #[test]
    fn nested_spans_share_the_timeline() {
        // an outer sweep span recorded around two inner discharge
        // spans must contain both on the tracer's single clock
        let mut t = Tracer::new(16);
        let outer = Instant::now();
        let inner_a = Instant::now();
        let da = Duration::from_micros(300);
        t.span_at(EventName::Discharge, inner_a, da, 0, 0, 0);
        let inner_b = Instant::now();
        t.span_at(EventName::Discharge, inner_b, Duration::from_micros(200), 0, 1, 0);
        t.span_at(EventName::Sweep, outer, outer.elapsed() + da, 0, NONE, 0);
        let evs = t.events();
        assert_eq!(evs.len(), 3);
        let sweep = evs[2];
        for inner in &evs[..2] {
            assert!(sweep.ts_us <= inner.ts_us, "outer starts first");
            assert!(
                inner.ts_us + inner.dur_us <= sweep.ts_us + sweep.dur_us,
                "inner span ends inside the outer span"
            );
        }
    }

    #[test]
    fn overflow_increments_the_drop_counter_without_reallocating() {
        let mut t = Tracer::new(4);
        let cap_before = t.buf.capacity();
        for i in 0..10 {
            t.instant(EventName::PrefetchHit, 0, i, 0);
        }
        assert_eq!(t.len(), 4, "buffer is bounded");
        assert_eq!(t.dropped(), 6, "overflow counted, not grown");
        assert_eq!(t.buf.capacity(), cap_before, "never reallocates");
        // draining hands the events over and resets the drop counter,
        // still without touching the allocation
        let (events, dropped) = t.take_batch();
        assert_eq!((events.len(), dropped), (4, 6));
        assert_eq!(t.dropped(), 0);
        assert_eq!(t.buf.capacity(), cap_before);
        t.instant(EventName::PrefetchMiss, 0, 0, 0);
        assert_eq!(t.len(), 1, "reusable after a drain");
    }

    #[test]
    fn disabled_tracer_records_nothing_but_keeps_a_clock() {
        let mut t = Tracer::disabled();
        t.instant(EventName::WireSend, 0, 0, 0);
        t.span_at(EventName::Sweep, Instant::now(), Duration::from_secs(1), 0, NONE, 0);
        assert!(t.is_empty());
        assert_eq!(t.dropped(), 0);
        let a = t.now_us();
        let b = t.now_us();
        assert!(b >= a, "clock is monotonic even when disabled");
        // late arming (the worker path) starts recording
        t.enable(8);
        t.instant(EventName::WireRecv, 0, 0, 0);
        assert_eq!(t.len(), 1);
    }

    #[test]
    fn sweep_rollup_tracks_min_mean_max() {
        let mut r = SweepRollup::default();
        assert_eq!(r.mean(), Duration::ZERO);
        for ms in [30u64, 10, 20] {
            r.add(Duration::from_millis(ms));
        }
        assert_eq!(r.count, 3);
        assert_eq!(r.min, Duration::from_millis(10));
        assert_eq!(r.max, Duration::from_millis(30));
        assert_eq!(r.mean(), Duration::from_millis(20));
    }
}
