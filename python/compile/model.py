"""L2: the JAX compute graph around the L1 wave kernel.

``grid_pr_sweeps`` runs ``iters`` lock-step push-relabel waves over the
region plane-stack with a single fused ``lax.fori_loop`` (one XLA while
loop; all planes are loop carries, so nothing is re-materialized between
waves) and accumulates the flow routed to the sink. It is lowered once
by :mod:`compile.aot` to HLO text and executed from the rust runtime —
Python never runs on the solve path.
"""

import functools

import jax
import jax.numpy as jnp

from compile.kernels import grid_pr


@functools.partial(jax.jit, static_argnames=("iters", "interpret"))
def grid_pr_sweeps(e, d, cn, cs, ce, cw, sc, frozen, dinf, iters=32, interpret=True):
    """Run ``iters`` waves; returns the updated planes plus the total
    flow pushed to the sink (``int32[1, 1]``)."""

    def body(_, state):
        e, d, cn, cs, ce, cw, sc, flow = state
        e, d, cn, cs, ce, cw, sc, df = grid_pr.wave(
            e, d, cn, cs, ce, cw, sc, frozen, dinf, interpret=interpret
        )
        return (e, d, cn, cs, ce, cw, sc, flow + df)

    flow0 = jnp.zeros((1, 1), dtype=jnp.int32)
    state = jax.lax.fori_loop(0, iters, body, (e, d, cn, cs, ce, cw, sc, flow0))
    return state


def example_args(h, w):
    """ShapeDtypeStructs for AOT lowering of an ``h × w`` region."""
    plane = jax.ShapeDtypeStruct((h, w), jnp.int32)
    scalar = jax.ShapeDtypeStruct((1, 1), jnp.int32)
    return (plane,) * 7 + (plane, scalar)
