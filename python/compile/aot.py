"""AOT lowering: jax → HLO *text* artifacts for the rust PJRT runtime.

HLO text (not ``.serialize()``): jax ≥ 0.5 emits HloModuleProto with
64-bit instruction ids which xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Artifacts (``make artifacts``):

* ``grid_pr_<H>x<W>.hlo.txt`` — ``iters`` waves of the L1 kernel over an
  ``H × W`` plane-stack, for each configured shape;
* ``model.hlo.txt`` — alias of the default 64×64 artifact (the Makefile
  staleness anchor).

Usage: ``python -m compile.aot --out ../artifacts/model.hlo.txt``
"""

import argparse
import os
import shutil

import jax
from jax._src.lib import xla_client as xc

from compile import model

# (H, W, waves-per-call) artifacts built by default: a 64×64 whole-grid
# solver and a 34×34 tile (32×32 region + 1-cell frozen halo) for the
# tiled accelerated coordinator.
SHAPES = [(64, 64, 32), (34, 34, 32)]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_grid_pr(h: int, w: int, iters: int) -> str:
    args = model.example_args(h, w)
    lowered = jax.jit(
        lambda *a: model.grid_pr_sweeps(*a, iters=iters, interpret=True)
    ).lower(*args)
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts/model.hlo.txt")
    ap.add_argument(
        "--shapes",
        default=",".join(f"{h}x{w}x{i}" for h, w, i in SHAPES),
        help="comma-separated HxWxITERS triples",
    )
    ns = ap.parse_args()
    out_dir = os.path.dirname(os.path.abspath(ns.out))
    os.makedirs(out_dir, exist_ok=True)

    default_path = None
    for spec in ns.shapes.split(","):
        h, w, iters = (int(x) for x in spec.split("x"))
        text = lower_grid_pr(h, w, iters)
        path = os.path.join(out_dir, f"grid_pr_{h}x{w}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {len(text)} chars to {path} ({iters} waves/call)")
        if default_path is None:
            default_path = path

    shutil.copyfile(default_path, ns.out)
    print(f"wrote {ns.out} (alias of {os.path.basename(default_path)})")


if __name__ == "__main__":
    main()
