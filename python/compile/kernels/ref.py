"""Pure-jnp oracle for the lock-step push-relabel wave.

Written *independently* of the kernel (explicit zero-padded slicing
instead of rolls, gather-style formulation of the relabel) so the
pytest comparison against :mod:`grid_pr` is meaningful. Also hosts a
slow, pure-python maxflow (BFS Ford–Fulkerson on the grid) used by the
convergence tests.
"""

import numpy as np

# JAX is optional: `maxflow_grid` (the pure-python/NumPy oracle the CI
# gate runs everywhere) must import without it; only `wave_ref` needs
# jnp, and raises a clear error when JAX is absent.
try:
    import jax.numpy as jnp
except ImportError:  # pragma: no cover - exercised on JAX-less CI
    jnp = None


def _shift(a, dy, dx, fill):
    """a shifted so that out[y, x] = a[y+dy, x+dx] (fill outside)."""
    out = np.full_like(np.asarray(a), fill)
    h, w = a.shape
    ys = slice(max(0, -dy), min(h, h - dy))
    xs = slice(max(0, -dx), min(w, w - dx))
    ysrc = slice(max(0, dy), min(h, h + dy))
    xsrc = slice(max(0, dx), min(w, w + dx))
    out[ys, xs] = np.asarray(a)[ysrc, xsrc]
    return jnp.asarray(out)


def wave_ref(e, d, cn, cs, ce, cw, sc, frozen, dinf):
    """One lock-step wave; same contract as grid_pr.wave (minus jit)."""
    if jnp is None:
        raise RuntimeError("ref.wave_ref requires JAX; only maxflow_grid is NumPy-pure")
    dinf = int(np.asarray(dinf).reshape(()))
    thawed = frozen == 0

    # push to sink
    delta = jnp.where((e > 0) & (d == 1) & (sc > 0) & thawed, jnp.minimum(e, sc), 0)
    e = e - delta
    sc = sc - delta
    flow = int(jnp.sum(delta))

    # pushes; order must match the kernel: N, S, W, E
    # direction: (cap, reverse cap, dy, dx) where (dy,dx) is the neighbor
    for cap_name, rev_name, dy, dx in (
        ("cn", "cs", -1, 0),
        ("cs", "cn", 1, 0),
        ("cw", "ce", 0, -1),
        ("ce", "cw", 0, 1),
    ):
        caps = {"cn": cn, "cs": cs, "ce": ce, "cw": cw}
        cap = caps[cap_name]
        d_nbr = _shift(d, dy, dx, fill=2 * dinf + 5)  # border: inadmissible
        ok = (e > 0) & (d < dinf) & (cap > 0) & (d == d_nbr + 1) & thawed
        dd = jnp.where(ok, jnp.minimum(e, cap), 0)
        e = e - dd
        cap = cap - dd
        arrived = _shift(dd, -dy, -dx, fill=0)
        e = e + arrived
        rev = caps[rev_name] + arrived
        caps[cap_name] = cap
        caps[rev_name] = rev
        cn, cs, ce, cw = caps["cn"], caps["cs"], caps["ce"], caps["cw"]

    # relabel
    big = dinf
    cand = jnp.where(sc > 0, 1, big)
    for cap, dy, dx in ((cn, -1, 0), (cs, 1, 0), (cw, 0, -1), (ce, 0, 1)):
        d_nbr = _shift(d, dy, dx, fill=big)
        cand = jnp.minimum(cand, jnp.where(cap > 0, d_nbr + 1, big))
    active = (e > 0) & (d < dinf) & thawed
    d = jnp.where(active, jnp.maximum(d, jnp.minimum(cand, big)), d)

    return e, d, cn, cs, ce, cw, sc, jnp.asarray([[flow]], dtype=jnp.int32)


# ---------------------------------------------------------------------------
# pure-python maxflow oracle on the grid (BFS augmentation)
# ---------------------------------------------------------------------------


def maxflow_grid(e, cn, cs, ce, cw, sc):
    """Max preflow value of the grid network: excess `e` routed to the
    implicit sink through n-links and `sc` sink arcs."""
    e = np.asarray(e).astype(np.int64).copy()
    sc = np.asarray(sc).astype(np.int64).copy()
    caps = {
        (-1, 0): np.asarray(cn).astype(np.int64).copy(),
        (1, 0): np.asarray(cs).astype(np.int64).copy(),
        (0, -1): np.asarray(cw).astype(np.int64).copy(),
        (0, 1): np.asarray(ce).astype(np.int64).copy(),
    }
    h, w = e.shape
    total = 0
    while True:
        # BFS from all excess nodes toward any node with sink capacity
        parent = {}
        frontier = [(y, x) for y in range(h) for x in range(w) if e[y, x] > 0]
        for f in frontier:
            parent[f] = None
        goal = None
        qi = 0
        while qi < len(frontier):
            v = frontier[qi]
            qi += 1
            if sc[v] > 0:
                goal = v
                break
            for (dy, dx), cap in caps.items():
                u = (v[0] + dy, v[1] + dx)
                if 0 <= u[0] < h and 0 <= u[1] < w and u not in parent and cap[v] > 0:
                    parent[u] = (v, (dy, dx))
                    frontier.append(u)
        if goal is None:
            return total
        # walk back, find bottleneck
        path = []
        v = goal
        while parent[v] is not None:
            prev, d = parent[v]
            path.append((prev, d))
            v = prev
        root = v
        bottleneck = min([e[root], sc[goal]] + [caps[d][v] for v, d in path])
        e[root] -= bottleneck
        sc[goal] -= bottleneck
        for v, d in path:
            caps[d][v] -= bottleneck
            rd = (-d[0], -d[1])
            u = (v[0] + d[0], v[1] + d[1])
            caps[rd][u] += bottleneck
        total += bottleneck
