"""L1: Pallas kernel — one lock-step (Jacobi) push-relabel *wave* on a
4-connected 2-D grid region.

This is the accelerated region discharge the paper's Conclusion proposes
("4) sequential, using GPU for solving region discharge"), re-thought for
a TPU-shaped accelerator (see DESIGN.md §Hardware-Adaptation): the whole
region plane-stack lives in VMEM as dense ``int32[H, W]`` planes, pushes
are whole-plane vectorized shifted adds on the VPU (no atomics — the
lock-step wave computes out-flows per direction, then in-flows as shifted
copies), and the HBM↔VMEM schedule is a single BlockSpec over the stack.

State planes (all ``int32[H, W]``):

* ``e``      — excess (source supply still parked at the node);
* ``d``      — distance label (``0 .. d_inf``);
* ``cn/cs/ce/cw`` — residual capacity toward the north/south/east/west
  neighbor (border-pointing capacities MUST be zero);
* ``sc``     — residual capacity of the ``(v, t)`` sink arc;
* ``frozen`` — 1 for halo/boundary cells: they never push or relabel,
  but absorb pushes (their excess is the region's exported flow).

Scalars: ``dinf`` — the label ceiling, as an ``int32[1, 1]`` plane so one
compiled artifact serves any global ceiling; ``flow`` — flow routed to
the sink by this wave (accumulated by the L2 loop).

One wave =
  1. push-to-sink for nodes with ``d == 1``;
  2. four directional push passes (N, S, E, W sequentially, so excess is
     never overdrawn; lock-step is deadlock-free because
     ``d(u) = d(v)+1`` cannot hold in both directions);
  3. Jacobi relabel: active nodes rise to
     ``min(d_inf, min{d(v)+1 : residual arc})`` — a no-op whenever an
     admissible arc remains, so the unconditional ``max`` is exact.

Pallas runs with ``interpret=True`` (the CPU PJRT plugin cannot execute
Mosaic custom-calls); the lowered HLO is what the rust runtime executes.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _wave_math(e, d, cn, cs, ce, cw, sc, frozen, dinf):
    """The wave, expressed on plain jnp values (shared by the pallas
    kernel body; the *independent* oracle lives in ref.py)."""
    thawed = frozen == 0

    # ---- 1. push to sink -------------------------------------------------
    delta = jnp.where((e > 0) & (d == 1) & (sc > 0) & thawed, jnp.minimum(e, sc), 0)
    e = e - delta
    sc = sc - delta
    flow = jnp.sum(delta)

    # ---- 2. directional pushes -------------------------------------------
    # direction tables: (cap plane, axis, shift toward neighbor)
    # pushing north: neighbor (y-1, x) → neighbor value seen via roll(+1)
    def push(e, cap_out, cap_in_of_nbr, axis, shift):
        # label of the neighbor each node would push to
        d_nbr = jnp.roll(d, shift, axis=axis)
        ok = (e > 0) & (d < dinf) & (cap_out > 0) & (d == d_nbr + 1) & thawed
        dd = jnp.where(ok, jnp.minimum(e, cap_out), 0)
        e = e - dd
        cap_out = cap_out - dd
        arrived = jnp.roll(dd, -shift, axis=axis)  # lands at the neighbor
        e = e + arrived
        cap_in_of_nbr = cap_in_of_nbr + arrived
        return e, cap_out, cap_in_of_nbr

    # north: neighbor at y-1 ⇒ its value is roll(d, +1, axis=0); the
    # reverse arc of a north push is the receiver's *south* capacity.
    e, cn, cs = push(e, cn, cs, axis=0, shift=1)
    e, cs, cn = push(e, cs, cn, axis=0, shift=-1)
    e, cw, ce = push(e, cw, ce, axis=1, shift=1)
    e, ce, cw = push(e, ce, cw, axis=1, shift=-1)

    # ---- 3. Jacobi relabel -------------------------------------------------
    big = dinf
    cand = jnp.where(sc > 0, 1, big)
    cand = jnp.minimum(cand, jnp.where(cn > 0, jnp.roll(d, 1, axis=0) + 1, big))
    cand = jnp.minimum(cand, jnp.where(cs > 0, jnp.roll(d, -1, axis=0) + 1, big))
    cand = jnp.minimum(cand, jnp.where(cw > 0, jnp.roll(d, 1, axis=1) + 1, big))
    cand = jnp.minimum(cand, jnp.where(ce > 0, jnp.roll(d, -1, axis=1) + 1, big))
    active = (e > 0) & (d < dinf) & thawed
    d = jnp.where(active, jnp.maximum(d, jnp.minimum(cand, dinf)), d)

    return e, d, cn, cs, ce, cw, sc, flow


def _wave_kernel(
    e_ref, d_ref, cn_ref, cs_ref, ce_ref, cw_ref, sc_ref, frozen_ref, dinf_ref,
    e_o, d_o, cn_o, cs_o, ce_o, cw_o, sc_o, flow_o,
):
    dinf = dinf_ref[0, 0]
    out = _wave_math(
        e_ref[...], d_ref[...], cn_ref[...], cs_ref[...], ce_ref[...],
        cw_ref[...], sc_ref[...], frozen_ref[...], dinf,
    )
    e, d, cn, cs, ce, cw, sc, flow = out
    e_o[...] = e
    d_o[...] = d
    cn_o[...] = cn
    cs_o[...] = cs
    ce_o[...] = ce
    cw_o[...] = cw
    sc_o[...] = sc
    flow_o[...] = flow.reshape(1, 1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def wave(e, d, cn, cs, ce, cw, sc, frozen, dinf, interpret=True):
    """Run one lock-step wave via the Pallas kernel.

    All planes are ``int32[H, W]``; ``dinf`` is ``int32[1, 1]``. Returns
    the updated ``(e, d, cn, cs, ce, cw, sc)`` and the ``int32[1, 1]``
    flow pushed to the sink.
    """
    h, w = e.shape
    plane = jax.ShapeDtypeStruct((h, w), jnp.int32)
    out_shape = [plane] * 7 + [jax.ShapeDtypeStruct((1, 1), jnp.int32)]
    return pl.pallas_call(
        _wave_kernel,
        out_shape=out_shape,
        interpret=interpret,
    )(e, d, cn, cs, ce, cw, sc, frozen, dinf)
