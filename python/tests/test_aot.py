"""AOT artifact checks: the lowering pipeline produces parseable HLO
text with the expected entry signature, deterministically."""

import jax.numpy as jnp
import numpy as np

from compile import aot, model


def test_lowered_hlo_signature():
    text = aot.lower_grid_pr(8, 8, 4)
    assert "HloModule" in text
    assert "ENTRY" in text
    # 8 inputs of s32[8,8] + one s32[1,1] scalar
    assert text.count("s32[8,8]") > 8
    assert "s32[1,1]" in text
    # while-loop from the fori_loop
    assert "while" in text


def test_lowering_is_deterministic():
    a = aot.lower_grid_pr(6, 6, 2)
    b = aot.lower_grid_pr(6, 6, 2)
    assert a == b


def test_default_shapes_configured():
    # the rust runtime loads exactly these (GridAccel::load / aot.SHAPES)
    assert (64, 64, 32) in aot.SHAPES
    assert (34, 34, 32) in aot.SHAPES


def test_example_args_match_model():
    args = model.example_args(5, 7)
    assert len(args) == 9
    assert args[0].shape == (5, 7)
    assert args[-1].shape == (1, 1)
    # run the jitted model on zeros of those shapes — smoke of the full
    # L2 entry that gets lowered
    zeros = [jnp.zeros(a.shape, a.dtype) for a in args[:-1]]
    dinf = jnp.asarray([[37]], dtype=jnp.int32)
    out = model.grid_pr_sweeps(*zeros, dinf, iters=3)
    assert len(out) == 8
    assert int(np.asarray(out[-1]).reshape(())) == 0
