"""L2 correctness: iterating the wave converges to a maximum preflow —
the flow routed to the sink equals the pure-python Ford–Fulkerson value,
and trapped excess ends at the label ceiling."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import model
from compile.kernels import ref
from tests.test_kernel_vs_ref import random_state


def solve_to_convergence(state, max_calls=200, iters=16):
    e, d, cn, cs, ce, cw, sc, frozen, dinf = state
    total = 0
    for _ in range(max_calls):
        e, d, cn, cs, ce, cw, sc, flow = model.grid_pr_sweeps(
            e, d, cn, cs, ce, cw, sc, frozen, dinf, iters=iters
        )
        total += int(np.asarray(flow).reshape(()))
        active = np.asarray(
            (e > 0) & (d < int(np.asarray(dinf).reshape(()))) & (frozen == 0)
        )
        if not active.any():
            return (e, d, cn, cs, ce, cw, sc), total
    raise AssertionError("did not converge")


@pytest.mark.parametrize("seed", range(4))
@pytest.mark.parametrize("shape", [(5, 5), (7, 9)])
def test_converges_to_maxflow(seed, shape):
    state = random_state(*shape, seed=seed, strength=8, excess=12)
    e0, _, cn0, cs0, ce0, cw0, sc0, _, _ = state
    expect = ref.maxflow_grid(e0, cn0, cs0, ce0, cw0, sc0)
    (_, d, *_rest), total = solve_to_convergence(state)
    assert total == expect


@pytest.mark.parametrize("seed", range(2))
def test_trapped_excess_reaches_ceiling(seed):
    state = random_state(6, 6, seed=seed, strength=5, excess=10)
    # remove all sink capacity: everything is trapped
    e, d, cn, cs, ce, cw, sc, frozen, dinf = state
    sc = jnp.zeros_like(sc)
    (e, d, *_), total = solve_to_convergence(
        (e, d, cn, cs, ce, cw, sc, frozen, dinf)
    )
    assert total == 0
    e = np.asarray(e)
    d = np.asarray(d)
    ceiling = int(np.asarray(dinf).reshape(()))
    assert (d[e > 0] == ceiling).all()


def test_fori_loop_equals_manual_waves():
    from compile.kernels import grid_pr

    state = random_state(8, 8, seed=3)
    e, d, cn, cs, ce, cw, sc, frozen, dinf = state
    out = model.grid_pr_sweeps(e, d, cn, cs, ce, cw, sc, frozen, dinf, iters=7)
    e2, d2, cn2, cs2, ce2, cw2, sc2, flow2 = out
    total = 0
    for _ in range(7):
        e, d, cn, cs, ce, cw, sc, f = grid_pr.wave(e, d, cn, cs, ce, cw, sc, frozen, dinf)
        total += int(np.asarray(f).reshape(()))
    np.testing.assert_array_equal(np.asarray(e2), np.asarray(e))
    np.testing.assert_array_equal(np.asarray(d2), np.asarray(d))
    assert int(np.asarray(flow2).reshape(())) == total
