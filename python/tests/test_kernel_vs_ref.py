"""L1 correctness: the Pallas wave kernel vs the independent pure-jnp
oracle in ref.py, across seeded sweeps of shapes and capacity regimes
(hypothesis is unavailable offline; explicit seeds play its role)."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile.kernels import grid_pr, ref


def random_state(h, w, seed, strength=20, excess=30, frozen_halo=False):
    rng = np.random.RandomState(seed)
    e = rng.randint(0, excess + 1, size=(h, w)).astype(np.int32)
    sc = rng.randint(0, excess + 1, size=(h, w)).astype(np.int32)
    # a node holds excess or sink capacity, not both (excess form)
    keep_e = rng.rand(h, w) < 0.5
    e = np.where(keep_e, e, 0).astype(np.int32)
    sc = np.where(~keep_e, sc, 0).astype(np.int32)
    d = np.zeros((h, w), dtype=np.int32)
    caps = {}
    for name in ("cn", "cs", "ce", "cw"):
        caps[name] = rng.randint(0, strength + 1, size=(h, w)).astype(np.int32)
    # border-pointing capacities must be zero
    caps["cn"][0, :] = 0
    caps["cs"][-1, :] = 0
    caps["cw"][:, 0] = 0
    caps["ce"][:, -1] = 0
    frozen = np.zeros((h, w), dtype=np.int32)
    if frozen_halo:
        frozen[0, :] = frozen[-1, :] = 1
        frozen[:, 0] = frozen[:, -1] = 1
        e[frozen == 1] = 0
        sc[frozen == 1] = 0
    dinf = np.asarray([[h * w + 2]], dtype=np.int32)
    return (
        jnp.asarray(e),
        jnp.asarray(d),
        jnp.asarray(caps["cn"]),
        jnp.asarray(caps["cs"]),
        jnp.asarray(caps["ce"]),
        jnp.asarray(caps["cw"]),
        jnp.asarray(sc),
        jnp.asarray(frozen),
        jnp.asarray(dinf),
    )


def run_waves(fn, state, waves):
    e, d, cn, cs, ce, cw, sc, frozen, dinf = state
    total = 0
    for _ in range(waves):
        e, d, cn, cs, ce, cw, sc, flow = fn(e, d, cn, cs, ce, cw, sc, frozen, dinf)
        total += int(np.asarray(flow).reshape(()))
    return (e, d, cn, cs, ce, cw, sc), total


@pytest.mark.parametrize("seed", range(6))
@pytest.mark.parametrize("shape", [(4, 5), (8, 8), (13, 7)])
def test_wave_matches_ref(seed, shape):
    state = random_state(*shape, seed=seed)
    got, flow_k = run_waves(grid_pr.wave, state, waves=5)
    want, flow_r = run_waves(ref.wave_ref, state, waves=5)
    for g, w, name in zip(got, want, "e d cn cs ce cw sc".split()):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w), err_msg=name)
    assert flow_k == flow_r


@pytest.mark.parametrize("seed", range(3))
def test_wave_matches_ref_with_frozen_halo(seed):
    state = random_state(9, 9, seed=seed, frozen_halo=True)
    got, flow_k = run_waves(grid_pr.wave, state, waves=8)
    want, flow_r = run_waves(ref.wave_ref, state, waves=8)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
    assert flow_k == flow_r


def test_wave_invariants():
    """Capacities and excess stay non-negative; labels are monotone;
    total mass (excess + flow) is conserved."""
    state = random_state(10, 10, seed=42)
    e, d, cn, cs, ce, cw, sc, frozen, dinf = state
    mass0 = int(np.sum(np.asarray(e)))
    total = 0
    prev_d = np.asarray(d)
    for _ in range(20):
        e, d, cn, cs, ce, cw, sc, flow = grid_pr.wave(
            e, d, cn, cs, ce, cw, sc, frozen, dinf
        )
        total += int(np.asarray(flow).reshape(()))
        for plane in (e, cn, cs, ce, cw, sc):
            assert int(np.min(np.asarray(plane))) >= 0
        nd = np.asarray(d)
        assert (nd >= prev_d).all(), "labels are monotone"
        prev_d = nd
    assert int(np.sum(np.asarray(e))) + total == mass0, "mass conserved"


def test_frozen_cells_absorb_but_never_push():
    """Flow pushed into a frozen cell stays there as excess."""
    h = w = 5
    e = np.zeros((h, w), np.int32)
    e[2, 2] = 9
    sc = np.zeros((h, w), np.int32)
    caps = {n: np.full((h, w), 10, np.int32) for n in ("cn", "cs", "ce", "cw")}
    caps["cn"][0, :] = 0
    caps["cs"][-1, :] = 0
    caps["cw"][:, 0] = 0
    caps["ce"][:, -1] = 0
    frozen = np.zeros((h, w), np.int32)
    frozen[0, :] = frozen[-1, :] = 1
    frozen[:, 0] = frozen[:, -1] = 1
    d = np.zeros((h, w), np.int32)
    dinf = np.asarray([[h * w + 2]], np.int32)
    args = [jnp.asarray(x) for x in (e, d, caps["cn"], caps["cs"], caps["ce"], caps["cw"], sc, frozen, dinf)]
    state = tuple(args)
    e, d, cn, cs, ce, cw, sc2, frozen_, dinf_ = state
    for _ in range(30):
        e, d, cn, cs, ce, cw, sc2, flow = grid_pr.wave(
            e, d, cn, cs, ce, cw, sc2, frozen_, dinf_
        )
        assert int(np.asarray(flow).reshape(())) == 0, "no sink anywhere"
    e = np.asarray(e)
    halo = np.asarray(frozen_) == 1
    assert e[halo].sum() == 9, "all excess exported to the frozen halo"
    assert e[~halo].sum() == 0
