"""Skip the Pallas/JAX-dependent test modules when JAX is absent.

The CI rust/python gate runs `python -m pytest python/tests` in an
environment with only NumPy + pytest; the kernel/model/AOT suites need
JAX (and Pallas) and are collected only when it imports.
"""

collect_ignore = []

try:
    import jax  # noqa: F401
except ImportError:
    collect_ignore = [
        "test_aot.py",
        "test_kernel_vs_ref.py",
        "test_model_convergence.py",
    ]
