"""Pure-NumPy tests of the `ref.maxflow_grid` oracle — the only python
suite the default CI gate requires (it runs without JAX; see
conftest.py for how the JAX-dependent modules are skipped)."""

import numpy as np

from compile.kernels import ref


def grid(h, w, fill=0):
    return np.full((h, w), fill, dtype=np.int64)


def test_single_cell_self_absorption():
    # one cell with both excess and sink capacity: flow = min of the two
    e = grid(1, 1, 5)
    sc = grid(1, 1, 3)
    z = grid(1, 1)
    assert ref.maxflow_grid(e, z, z, z, z, sc) == 3


def test_chain_bottleneck():
    # excess at (0,0), sink at (0,2), east capacities 7 then 4 -> flow 4
    e = grid(1, 3)
    e[0, 0] = 100
    sc = grid(1, 3)
    sc[0, 2] = 100
    ce = grid(1, 3)
    ce[0, 0] = 7
    ce[0, 1] = 4
    z = grid(1, 3)
    assert ref.maxflow_grid(e, z, z, ce, z, sc) == 4


def test_disconnected_excess_is_trapped():
    e = grid(2, 2)
    e[0, 0] = 10
    sc = grid(2, 2)
    sc[1, 1] = 10
    z = grid(2, 2)
    # no n-link capacity at all: nothing can move
    assert ref.maxflow_grid(e, z, z, z, z, sc) == 0


def test_two_disjoint_paths():
    # 2x2: excess at both left cells, sinks at both right cells,
    # east capacity 5 on each row -> flow 10
    e = grid(2, 2)
    e[:, 0] = 20
    sc = grid(2, 2)
    sc[:, 1] = 20
    ce = grid(2, 2)
    ce[:, 0] = 5
    z = grid(2, 2)
    assert ref.maxflow_grid(e, z, z, ce, z, sc) == 10


def test_flow_uses_reverse_residuals():
    # a routing that forces an augmenting path through a reverse
    # residual arc: classic 2x2 cross with a tempting wrong first path
    e = grid(2, 2)
    e[0, 0] = 2
    sc = grid(2, 2)
    sc[1, 1] = 2
    cs = grid(2, 2)
    cs[0, 0] = 1  # (0,0) -> (1,0)
    cs[0, 1] = 1  # (0,1) -> (1,1)
    ce = grid(2, 2)
    ce[0, 0] = 1  # (0,0) -> (0,1)
    ce[1, 0] = 1  # (1,0) -> (1,1)
    z = grid(2, 2)
    assert ref.maxflow_grid(e, z, cs, ce, z, sc) == 2


def test_random_grids_conserve_and_bound():
    rng = np.random.RandomState(7)
    for _ in range(10):
        h, w = rng.randint(2, 6, size=2)
        e = rng.randint(0, 15, size=(h, w)).astype(np.int64)
        sc = rng.randint(0, 15, size=(h, w)).astype(np.int64)
        keep = rng.rand(h, w) < 0.5
        e = np.where(keep, e, 0)
        sc = np.where(~keep, sc, 0)
        caps = [rng.randint(0, 9, size=(h, w)).astype(np.int64) for _ in range(4)]
        cn, cs, ce, cw = caps
        cn[0, :] = 0
        cs[-1, :] = 0
        cw[:, 0] = 0
        ce[:, -1] = 0
        flow = ref.maxflow_grid(e, cn, cs, ce, cw, sc)
        assert 0 <= flow <= min(e.sum(), sc.sum())


def test_deterministic():
    rng = np.random.RandomState(3)
    e = rng.randint(0, 10, size=(4, 4)).astype(np.int64)
    sc = rng.randint(0, 10, size=(4, 4)).astype(np.int64)
    c = [rng.randint(0, 6, size=(4, 4)).astype(np.int64) for _ in range(4)]
    c[0][0, :] = 0
    c[1][-1, :] = 0
    c[3][:, 0] = 0
    c[2][:, -1] = 0
    a = ref.maxflow_grid(e, c[0], c[1], c[2], c[3], sc)
    b = ref.maxflow_grid(e, c[0], c[1], c[2], c[3], sc)
    assert a == b
