"""Tests for scripts/bench_trend.py (the BENCH_*.json trend differ).

The script lives outside the python package tree, so it is loaded by
file path; it is stdlib-only and must run on the CI runner's system
python3.
"""

import importlib.util
import json
from pathlib import Path

import pytest

SCRIPT = Path(__file__).resolve().parents[2] / "scripts" / "bench_trend.py"

spec = importlib.util.spec_from_file_location("bench_trend", SCRIPT)
bench_trend = importlib.util.module_from_spec(spec)
spec.loader.exec_module(bench_trend)


def write_bench(dirpath: Path, bench_id: str, records):
    dirpath.mkdir(parents=True, exist_ok=True)
    doc = {"bench": bench_id, "schema": 3, "quick": True,
           "experiment_wall_seconds": None, "records": records}
    (dirpath / f"BENCH_{bench_id}.json").write_text(json.dumps(doc))


def rec(case="g", solver="S-ARD", flow=42, wall=1.0, stored=0):
    return {"case": case, "solver": solver, "flow": flow,
            "sweeps": 3, "discharges": 9, "wall_seconds": wall,
            "converged": True, "page_stored_bytes": stored}


def test_matching_flows_exit_zero(tmp_path, capsys):
    write_bench(tmp_path / "cur", "fig6", [rec(wall=1.2, stored=100)])
    write_bench(tmp_path / "base", "fig6", [rec(wall=1.0, stored=120)])
    code = bench_trend.main([str(tmp_path / "cur"), str(tmp_path / "base")])
    out = capsys.readouterr().out
    assert code == 0
    assert "0 flow mismatch(es)" in out
    assert "+20.0%" in out  # wall-time delta reported
    assert "pages" in out  # schema-3 disk bytes reported


def test_flow_mismatch_exits_one(tmp_path, capsys):
    write_bench(tmp_path / "cur", "fig6", [rec(flow=42)])
    write_bench(tmp_path / "base", "fig6", [rec(flow=41)])
    code = bench_trend.main([str(tmp_path / "cur"), str(tmp_path / "base")])
    out = capsys.readouterr().out
    assert code == 1
    assert "FLOW MISMATCH" in out


def test_missing_baseline_is_ok(tmp_path, capsys):
    write_bench(tmp_path / "cur", "fig6", [rec()])
    code = bench_trend.main([str(tmp_path / "cur"), str(tmp_path / "nowhere")])
    assert code == 0
    assert "first run" in capsys.readouterr().out


def test_missing_current_is_an_error(tmp_path):
    assert bench_trend.main([str(tmp_path / "nope"), str(tmp_path)]) == 2


def test_new_and_disappeared_records_are_advisory(tmp_path, capsys):
    write_bench(tmp_path / "cur", "fig6", [rec(solver="S-ARD"), rec(solver="BK")])
    write_bench(tmp_path / "base", "fig6", [rec(solver="S-ARD"), rec(solver="HPR")])
    code = bench_trend.main([str(tmp_path / "cur"), str(tmp_path / "base")])
    out = capsys.readouterr().out
    assert code == 0
    assert "new record" in out
    assert "disappeared" in out


def test_slowdown_marker(tmp_path, capsys):
    write_bench(tmp_path / "cur", "fig6", [rec(wall=2.0)])
    write_bench(tmp_path / "base", "fig6", [rec(wall=1.0)])
    code = bench_trend.main(
        [str(tmp_path / "cur"), str(tmp_path / "base"), "--wall-warn-pct", "50"])
    out = capsys.readouterr().out
    assert code == 0, "slowdowns are advisory"
    assert "[slower]" in out


def test_corrupt_json_is_skipped_not_fatal(tmp_path, capsys):
    write_bench(tmp_path / "cur", "fig6", [rec()])
    write_bench(tmp_path / "base", "fig6", [rec()])
    (tmp_path / "cur" / "BENCH_bad.json").write_text("{not json")
    code = bench_trend.main([str(tmp_path / "cur"), str(tmp_path / "base")])
    out = capsys.readouterr().out
    assert code == 0
    assert "skipping unreadable" in out


@pytest.mark.parametrize("stored,expect", [(0, False), (77, True)])
def test_disk_bytes_only_shown_when_present(tmp_path, capsys, stored, expect):
    write_bench(tmp_path / "cur", "t1", [rec(stored=stored)])
    write_bench(tmp_path / "base", "t1", [rec(stored=stored)])
    bench_trend.main([str(tmp_path / "cur"), str(tmp_path / "base")])
    assert ("pages" in capsys.readouterr().out) is expect
