"""Tests for scripts/bench_trend.py (the BENCH_*.json trend differ).

The script lives outside the python package tree, so it is loaded by
file path; it is stdlib-only and must run on the CI runner's system
python3.
"""

import importlib.util
import json
from pathlib import Path

import pytest

SCRIPT = Path(__file__).resolve().parents[2] / "scripts" / "bench_trend.py"

spec = importlib.util.spec_from_file_location("bench_trend", SCRIPT)
bench_trend = importlib.util.module_from_spec(spec)
spec.loader.exec_module(bench_trend)

#: The committed record schema (`armincut analyze --emit-schema`); the
#: fixtures below are built from it so they always validate.
SCHEMA = json.loads(
    (Path(__file__).resolve().parents[2] / "scripts" /
     "schema_fields.json").read_text())


def write_bench(dirpath: Path, bench_id: str, records, schema=None):
    dirpath.mkdir(parents=True, exist_ok=True)
    doc = {"bench": bench_id, "schema": schema or SCHEMA["schema"],
           "quick": True, "experiment_wall_seconds": None,
           "records": records}
    (dirpath / f"BENCH_{bench_id}.json").write_text(json.dumps(doc))


def rec(case="g", solver="S-ARD", flow=42, wall=1.0, stored=0):
    r = {f: 0 for f in SCHEMA["fields"]}
    r.update({"case": case, "solver": solver, "flow": flow,
              "sweeps": 3, "discharges": 9, "wall_seconds": wall,
              "converged": True, "page_stored_bytes": stored})
    return r


def test_matching_flows_exit_zero(tmp_path, capsys):
    write_bench(tmp_path / "cur", "fig6", [rec(wall=1.2, stored=100)])
    write_bench(tmp_path / "base", "fig6", [rec(wall=1.0, stored=120)])
    code = bench_trend.main([str(tmp_path / "cur"), str(tmp_path / "base")])
    out = capsys.readouterr().out
    assert code == 0
    assert "0 flow mismatch(es)" in out
    assert "+20.0%" in out  # wall-time delta reported
    assert "pages" in out  # schema-3 disk bytes reported


def test_flow_mismatch_exits_one(tmp_path, capsys):
    write_bench(tmp_path / "cur", "fig6", [rec(flow=42)])
    write_bench(tmp_path / "base", "fig6", [rec(flow=41)])
    code = bench_trend.main([str(tmp_path / "cur"), str(tmp_path / "base")])
    out = capsys.readouterr().out
    assert code == 1
    assert "FLOW MISMATCH" in out


def test_missing_baseline_is_ok(tmp_path, capsys):
    write_bench(tmp_path / "cur", "fig6", [rec()])
    code = bench_trend.main([str(tmp_path / "cur"), str(tmp_path / "nowhere")])
    assert code == 0
    assert "first run" in capsys.readouterr().out


def test_missing_current_is_an_error(tmp_path):
    assert bench_trend.main([str(tmp_path / "nope"), str(tmp_path)]) == 2


def test_new_and_disappeared_records_are_advisory(tmp_path, capsys):
    write_bench(tmp_path / "cur", "fig6", [rec(solver="S-ARD"), rec(solver="BK")])
    write_bench(tmp_path / "base", "fig6", [rec(solver="S-ARD"), rec(solver="HPR")])
    code = bench_trend.main([str(tmp_path / "cur"), str(tmp_path / "base")])
    out = capsys.readouterr().out
    assert code == 0
    assert "new record" in out
    assert "disappeared" in out


def test_slowdown_marker(tmp_path, capsys):
    write_bench(tmp_path / "cur", "fig6", [rec(wall=2.0)])
    write_bench(tmp_path / "base", "fig6", [rec(wall=1.0)])
    code = bench_trend.main(
        [str(tmp_path / "cur"), str(tmp_path / "base"), "--wall-warn-pct", "50"])
    out = capsys.readouterr().out
    assert code == 0, "slowdowns are advisory"
    assert "[slower]" in out


def test_corrupt_json_is_skipped_not_fatal(tmp_path, capsys):
    write_bench(tmp_path / "cur", "fig6", [rec()])
    write_bench(tmp_path / "base", "fig6", [rec()])
    (tmp_path / "cur" / "BENCH_bad.json").write_text("{not json")
    code = bench_trend.main([str(tmp_path / "cur"), str(tmp_path / "base")])
    out = capsys.readouterr().out
    assert code == 0
    assert "skipping unreadable" in out


@pytest.mark.parametrize("stored,expect", [(0, False), (77, True)])
def test_disk_bytes_only_shown_when_present(tmp_path, capsys, stored, expect):
    write_bench(tmp_path / "cur", "t1", [rec(stored=stored)])
    write_bench(tmp_path / "base", "t1", [rec(stored=stored)])
    bench_trend.main([str(tmp_path / "cur"), str(tmp_path / "base")])
    assert ("pages" in capsys.readouterr().out) is expect


def wire_rec(sent=1000, recv=900, raw=5000, sync=0.25):
    r = rec(solver="D-ARD(2)")
    r.update({"wire_bytes_sent": sent, "wire_bytes_recv": recv,
              "wire_raw_bytes": raw, "sync_wall_seconds": sync,
              "dist_batches": 6, "max_inflight_discharges": 4,
              "par_sweep_seconds": 0.5})
    return r


def test_wire_bytes_delta_shown_for_distributed_records(tmp_path, capsys):
    write_bench(tmp_path / "cur", "table2", [wire_rec(sent=1200)])
    write_bench(tmp_path / "base", "table2", [wire_rec(sent=1000)])
    code = bench_trend.main([str(tmp_path / "cur"), str(tmp_path / "base")])
    out = capsys.readouterr().out
    assert code == 0
    assert "wire" in out and "2100B" in out  # 1200 + 900 current total


def fault_rec(restarts=1, ckpt=4096, recovery=0.75):
    r = wire_rec()
    r.update({"worker_restarts": restarts, "checkpoint_bytes": ckpt,
              "recovery_wall_seconds": recovery})
    return r


def test_worker_restarts_delta_shown_for_recovered_records(tmp_path, capsys):
    write_bench(tmp_path / "cur", "table2", [fault_rec(restarts=2)])
    write_bench(tmp_path / "base", "table2", [fault_rec(restarts=1)])
    code = bench_trend.main([str(tmp_path / "cur"), str(tmp_path / "base")])
    out = capsys.readouterr().out
    assert code == 0, "restart-count moves are advisory"
    assert "restarts 1 -> 2" in out


def test_restart_free_records_stay_silent_about_recovery(tmp_path, capsys):
    write_bench(tmp_path / "cur", "table2", [wire_rec()])
    write_bench(tmp_path / "base", "table2", [wire_rec()])
    bench_trend.main([str(tmp_path / "cur"), str(tmp_path / "base")])
    assert "restarts" not in capsys.readouterr().out


def test_schema6_fields_survive_into_history(tmp_path):
    hist = tmp_path / "history.jsonl"
    write_bench(tmp_path / "cur", "table2", [fault_rec()])
    code = bench_trend.main(
        [str(tmp_path / "cur"), str(tmp_path / "nowhere"), "--history", str(hist)])
    assert code == 0
    r = json.loads(hist.read_text())["records"][0]
    assert r["worker_restarts"] == 1
    assert r["checkpoint_bytes"] == 4096
    assert r["recovery_wall_seconds"] == 0.75


def test_schema6_fields_default_to_zero_for_old_records(tmp_path):
    # a genuinely old-style partial record: skip validation (it would
    # rightly flag it) and check the history defaults the gaps to 0
    hist = tmp_path / "history.jsonl"
    old = {"case": "g", "solver": "S-ARD", "flow": 42, "sweeps": 3,
           "discharges": 9, "wall_seconds": 1.0, "converged": True,
           "page_stored_bytes": 0}
    write_bench(tmp_path / "cur", "fig6", [old], schema=3)
    bench_trend.main(
        [str(tmp_path / "cur"), str(tmp_path / "nowhere"), "--history", str(hist),
         "--schema", str(tmp_path / "no_schema.json")])
    r = json.loads(hist.read_text())["records"][0]
    assert r["worker_restarts"] == 0
    assert r["checkpoint_bytes"] == 0
    assert r["recovery_wall_seconds"] == 0


def test_history_appends_and_trims(tmp_path, capsys):
    hist = tmp_path / "deep" / "history.jsonl"
    write_bench(tmp_path / "cur", "fig6", [wire_rec()])
    for i in range(4):
        code = bench_trend.main(
            [str(tmp_path / "cur"), str(tmp_path / "nowhere"),
             "--history", str(hist), "--history-max", "3",
             "--run-label", f"run{i}"])
        assert code == 0, "no baseline stays exit 0 with history on"
    lines = [json.loads(l) for l in hist.read_text().splitlines()]
    assert len(lines) == 3, "trimmed to --history-max"
    assert [l["run"] for l in lines] == ["run1", "run2", "run3"]
    r = lines[-1]["records"][0]
    assert r["bench"] == "fig6" and r["solver"] == "D-ARD(2)"
    # schema-4 wire fields survive into the condensed history
    assert r["wire_bytes_sent"] == 1000 and r["wire_raw_bytes"] == 5000
    assert r["sync_wall_seconds"] == 0.25
    # schema-5 parallel-sweep fields survive too
    assert r["dist_batches"] == 6
    assert r["max_inflight_discharges"] == 4
    assert r["par_sweep_seconds"] == 0.5
    # older-schema fields missing from the record default to 0
    assert r["page_raw_bytes"] == 0
    assert "history: 3 run(s)" in capsys.readouterr().out


def test_history_written_even_on_flow_mismatch(tmp_path):
    hist = tmp_path / "history.jsonl"
    write_bench(tmp_path / "cur", "fig6", [rec(flow=42)])
    write_bench(tmp_path / "base", "fig6", [rec(flow=41)])
    code = bench_trend.main(
        [str(tmp_path / "cur"), str(tmp_path / "base"), "--history", str(hist)])
    assert code == 1, "mismatch still exits 1"
    assert hist.is_file(), "the run is recorded regardless"


def test_history_drops_corrupt_lines(tmp_path):
    hist = tmp_path / "history.jsonl"
    hist.write_text('{"run": "old", "records": []}\nNOT JSON\n')
    write_bench(tmp_path / "cur", "fig6", [rec()])
    bench_trend.main(
        [str(tmp_path / "cur"), str(tmp_path / "nowhere"), "--history", str(hist)])
    lines = hist.read_text().splitlines()
    assert len(lines) == 2
    assert json.loads(lines[0])["run"] == "old"


def trace_rec(events=500, dropped=3, discharge=0.4, fuse=0.1):
    r = wire_rec()
    r.update({"trace_events": events, "trace_dropped": dropped,
              "discharge_seconds": discharge, "fuse_seconds": fuse})
    return r


def test_schema7_fields_survive_into_history(tmp_path):
    hist = tmp_path / "history.jsonl"
    write_bench(tmp_path / "cur", "table2", [trace_rec()])
    code = bench_trend.main(
        [str(tmp_path / "cur"), str(tmp_path / "nowhere"), "--history", str(hist)])
    assert code == 0
    r = json.loads(hist.read_text())["records"][0]
    assert r["trace_events"] == 500
    assert r["trace_dropped"] == 3
    assert r["discharge_seconds"] == 0.4
    assert r["fuse_seconds"] == 0.1


# --- --plot SVG trend curves ---


def test_plot_without_history_is_a_usage_error(tmp_path, capsys):
    write_bench(tmp_path / "cur", "fig6", [rec()])
    code = bench_trend.main(
        [str(tmp_path / "cur"), str(tmp_path / "nowhere"),
         "--plot", str(tmp_path / "plots")])
    assert code == 2
    assert "--plot needs --history" in capsys.readouterr().out


def test_plot_renders_svg_curves_from_history(tmp_path, capsys):
    hist = tmp_path / "history.jsonl"
    plots = tmp_path / "plots"
    for wall in (1.0, 1.5):
        write_bench(tmp_path / "cur", "table2",
                    [wire_rec(), rec(wall=wall)])
        code = bench_trend.main(
            [str(tmp_path / "cur"), str(tmp_path / "nowhere"),
             "--history", str(hist), "--plot", str(plots)])
        assert code == 0
    wall_svg = (plots / "trend_wall_seconds.svg").read_text()
    assert wall_svg.startswith("<svg")
    assert "polyline" in wall_svg
    assert "S-ARD" in wall_svg and "D-ARD(2)" in wall_svg
    wire_svg = (plots / "trend_wire_bytes.svg").read_text()
    assert "D-ARD(2)" in wire_svg
    assert "S-ARD" not in wire_svg, "all-zero series are dropped"
    assert "polyline" in (plots / "trend_sync_wall_seconds.svg").read_text()
    assert not (plots / "trend_worker_restarts.svg").exists(), \
        "an identically-zero quantity renders no file"
    assert "SVG curve(s)" in capsys.readouterr().out


def test_plot_series_collects_gaps_and_derived_wire_sum():
    runs = [
        {"records": [{"bench": "b", "case": "c", "solver": "s",
                      "wire_bytes_sent": 10, "wire_bytes_recv": 5}]},
        {"records": []},  # the record skips a run
        {"records": [{"bench": "b", "case": "c", "solver": "s",
                      "wire_bytes_sent": 20, "wire_bytes_recv": 5}]},
    ]
    series = bench_trend.collect_series(runs, "wire_bytes")
    assert series == {"b c s": [(0, 15.0), (2, 25.0)]}


# --- record-schema validation against scripts/schema_fields.json ---


def test_drifted_record_missing_field_exits_one(tmp_path, capsys):
    # seed drift: the Rust writer (supposedly) stopped emitting
    # wire_raw_bytes — the record no longer matches the emitted schema
    drifted = rec()
    del drifted["wire_raw_bytes"]
    write_bench(tmp_path / "cur", "fig6", [drifted])
    write_bench(tmp_path / "base", "fig6", [rec()])
    code = bench_trend.main([str(tmp_path / "cur"), str(tmp_path / "base")])
    out = capsys.readouterr().out
    assert code == 1
    assert "schema drift" in out and "wire_raw_bytes" in out


def test_record_with_unknown_field_exits_one(tmp_path, capsys):
    drifted = rec()
    drifted["brand_new_counter"] = 7
    write_bench(tmp_path / "cur", "fig6", [drifted])
    write_bench(tmp_path / "base", "fig6", [rec()])
    code = bench_trend.main([str(tmp_path / "cur"), str(tmp_path / "base")])
    out = capsys.readouterr().out
    assert code == 1
    assert "unknown field" in out and "brand_new_counter" in out
    assert "--emit-schema" in out  # the fix is named in the message


def test_stale_schema_stamp_exits_one(tmp_path, capsys):
    write_bench(tmp_path / "cur", "fig6", [rec()], schema=3)
    write_bench(tmp_path / "base", "fig6", [rec()])
    code = bench_trend.main([str(tmp_path / "cur"), str(tmp_path / "base")])
    out = capsys.readouterr().out
    assert code == 1
    assert "schema 3 != expected" in out


def test_baseline_records_are_exempt_from_validation(tmp_path):
    # baselines may predate a schema bump; only the current run gates
    old = {"case": "g", "solver": "S-ARD", "flow": 42,
           "wall_seconds": 1.0}
    write_bench(tmp_path / "cur", "fig6", [rec()])
    write_bench(tmp_path / "base", "fig6", [old], schema=3)
    assert bench_trend.main(
        [str(tmp_path / "cur"), str(tmp_path / "base")]) == 0


def test_missing_schema_file_warns_but_does_not_gate(tmp_path, capsys):
    write_bench(tmp_path / "cur", "fig6", [rec()])
    code = bench_trend.main(
        [str(tmp_path / "cur"), str(tmp_path / "nowhere"),
         "--schema", str(tmp_path / "no_schema.json")])
    assert code == 0
    assert "skipping validation" in capsys.readouterr().out


def test_committed_schema_matches_the_tests_assumptions():
    # HISTORY_FIELDS in the script must be exactly the emitted
    # history_fields list, and every history field must be a record field
    assert list(bench_trend.HISTORY_FIELDS) == SCHEMA["history_fields"]
    assert set(SCHEMA["history_fields"]) <= set(SCHEMA["fields"])


# ---------------------------------------------------------------------------
# scripts/metric_names.json — the live-metrics series pin
# (`armincut analyze --emit-metrics`, checked by the metric-names gate)

METRIC_NAMES_PATH = (Path(__file__).resolve().parents[2] / "scripts" /
                     "metric_names.json")


def test_metric_names_pin_is_a_valid_sorted_unique_list():
    names = json.loads(METRIC_NAMES_PATH.read_text())
    assert isinstance(names, list) and names, "non-empty JSON array"
    assert all(isinstance(n, str) for n in names)
    assert names == sorted(names), "the pin is sorted (emit order)"
    assert len(names) == len(set(names)), "no duplicate series"


def test_metric_names_pin_uses_the_armincut_prefix_and_conventions():
    names = json.loads(METRIC_NAMES_PATH.read_text())
    for n in names:
        assert n.startswith("armincut_"), n
        assert all(c.islower() or c.isdigit() or c == "_" for c in n), n


def test_metric_names_pin_carries_the_series_ci_asserts_on():
    # the dist-smoke metrics leg greps for exactly these; renaming them
    # must show up here (and in the grow-only analyze gate) first
    names = set(json.loads(METRIC_NAMES_PATH.read_text()))
    assert {"armincut_sweeps_total",
            "armincut_worker_discharges_total",
            "armincut_flow_lower_bound",
            "armincut_sweep_wall_us"} <= names
