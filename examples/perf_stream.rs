//! Streaming-store perf probe: measure what the prefetch pipeline and
//! page compression buy on a §7.1-style grid solved one region at a
//! time from disk.
//!
//! ```sh
//! cargo run --release --example perf_stream            # 300×300 grid
//! cargo run --release --example perf_stream -- 600 16  # side, regions
//! ```
//!
//! Runs the same S-ARD streaming solve in the four store
//! configurations ({blocking, prefetch} × {raw, compressed}) and prints
//! the Fig. 10-style split: wall time, blocking vs overlapped disk
//! time, on-disk page bytes against their uncompressed size, and the
//! prefetch hit rate. All four runs must return the same flow — the
//! probe asserts it.

use armincut::coordinator::sequential::{solve_sequential, SeqOptions};
use armincut::core::partition::Partition;
use armincut::gen::synthetic2d::{synthetic_2d, Synthetic2dParams};

fn main() {
    let mut args = std::env::args().skip(1);
    let side: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(300);
    let k: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(9);
    let s = (k as f64).sqrt().round().max(1.0) as usize;

    println!("generating {side}x{side} grid (strength 150, seed 1), {}x{s} regions ...", s);
    let g = synthetic_2d(&Synthetic2dParams {
        width: side,
        height: side,
        strength: 150,
        seed: 1,
        ..Default::default()
    });
    let part = Partition::grid2d(side, side, s, s);
    println!(
        "instance: n = {}, m = {}, {} MB in memory\n",
        g.n(),
        g.num_arcs() / 2,
        g.memory_bytes() >> 20
    );

    let base = std::env::temp_dir().join(format!("armincut_perf_stream_{}", std::process::id()));
    let mut flows = Vec::new();
    println!(
        "{:>20} {:>9} {:>9} {:>9} {:>10} {:>10} {:>9}",
        "config", "wall s", "blk s", "ovl s", "pages MB", "raw MB", "hit rate"
    );
    for (name, prefetch, compress) in [
        ("blocking-raw", false, false),
        ("blocking-compressed", false, true),
        ("prefetch-raw", true, false),
        ("prefetch-compressed", true, true),
    ] {
        let mut o = SeqOptions::ard();
        o.streaming_dir = Some(base.join(name));
        o.streaming_prefetch = prefetch;
        o.streaming_compress = compress;
        let res = solve_sequential(&g, &part, &o).expect("streaming solve");
        let m = &res.metrics;
        assert!(m.converged, "{name} did not converge");
        let fetches = m.prefetch_hits + m.prefetch_misses;
        println!(
            "{:>20} {:>9.3} {:>9.3} {:>9.3} {:>10.1} {:>10.1} {:>8.0}%",
            name,
            m.t_total.as_secs_f64(),
            m.t_disk.as_secs_f64(),
            m.t_disk_overlapped.as_secs_f64(),
            m.page_stored_bytes as f64 / (1 << 20) as f64,
            m.page_raw_bytes as f64 / (1 << 20) as f64,
            if fetches > 0 { 100.0 * m.prefetch_hits as f64 / fetches as f64 } else { 0.0 },
        );
        flows.push(m.flow);
    }
    std::fs::remove_dir_all(&base).ok();
    assert!(flows.windows(2).all(|w| w[0] == w[1]), "store configs must agree: {flows:?}");
    println!(
        "\nflow = {} in all four configurations (store is invisible to the algorithm)",
        flows[0]
    );
    println!(
        "record the prefetch-compressed vs blocking-raw wall/blk columns in README's \
         streaming table"
    );
}
