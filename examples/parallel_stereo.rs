//! Parallel competition on a stereo-like instance (the paper's §7.3):
//! P-ARD on 1/2/4 threads vs sequential S-ARD vs whole-graph BK vs the
//! dual-decomposition baseline (which may fail to terminate — that is
//! the paper's observation, reproduced here faithfully).
//!
//! ```sh
//! cargo run --release --example parallel_stereo [WIDTH HEIGHT]
//! ```

use armincut::coordinator::dd::{solve_dd, DdOptions};
use armincut::coordinator::parallel::{solve_parallel, ParOptions};
use armincut::coordinator::sequential::{solve_sequential, SeqOptions};
use armincut::core::partition::Partition;
use armincut::gen::stereo::{stereo_bvz, StereoParams};
use armincut::solvers::{bk::Bk, MaxFlowSolver};
use std::time::Instant;

fn main() {
    let mut args = std::env::args().skip(1);
    let w: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(434);
    let h: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(380);
    println!("generating BVZ-like stereo instance {w}x{h} ...");
    let g = stereo_bvz(&StereoParams { width: w, height: h, ..Default::default() });
    println!("instance: n = {}, m = {}", g.n(), g.num_arcs() / 2);

    let partition = Partition::grid2d(w, h, 4, 4);
    println!("partition: 16 regions, |B| = {}", partition.stats(&g).boundary_nodes);

    let mut gc = g.clone();
    let t = Instant::now();
    let flow = Bk::new().solve(&mut gc);
    let t_bk = t.elapsed().as_secs_f64();
    println!("\n{:<12} {:>9} {:>8} {:>10}", "solver", "time s", "sweeps", "flow");
    println!("{:<12} {:>9.3} {:>8} {:>10}", "BK", t_bk, "-", flow);

    let seq = solve_sequential(&g, &partition, &SeqOptions::ard()).expect("solve");
    assert_eq!(seq.metrics.flow, flow);
    println!(
        "{:<12} {:>9.3} {:>8} {:>10}",
        "S-ARD",
        seq.metrics.t_total.as_secs_f64(),
        seq.metrics.sweeps,
        seq.metrics.flow
    );
    let t_seq = seq.metrics.t_total.as_secs_f64();

    let mut t_par4 = 0.0;
    for threads in [1usize, 2, 4] {
        let res = solve_parallel(&g, &partition, &ParOptions::ard(threads));
        assert_eq!(res.metrics.flow, flow, "P-ARD({threads})");
        let dt = res.metrics.t_total.as_secs_f64();
        if threads == 4 {
            t_par4 = dt;
        }
        println!(
            "{:<12} {:>9.3} {:>8} {:>10}",
            format!("P-ARD({threads})"),
            dt,
            res.metrics.sweeps,
            res.metrics.flow
        );
    }
    let prd = solve_parallel(&g, &partition, &ParOptions::prd(4));
    assert_eq!(prd.metrics.flow, flow);
    println!(
        "{:<12} {:>9.3} {:>8} {:>10}",
        "P-PRD(4)",
        prd.metrics.t_total.as_secs_f64(),
        prd.metrics.sweeps,
        prd.metrics.flow
    );

    for k in [2usize, 4] {
        let p = Partition::by_node_ranges(g.n(), k);
        let res = solve_dd(&g, &p, &DdOptions::default());
        println!(
            "{:<12} {:>9.3} {:>8} {:>10}{}",
            format!("DDx{k}"),
            res.metrics.t_total.as_secs_f64(),
            res.metrics.sweeps,
            res.metrics.flow,
            if res.metrics.converged { "" } else { "  [NOT CONVERGED]" }
        );
        if res.metrics.converged {
            assert_eq!(res.metrics.flow, flow);
        }
    }

    println!(
        "\nP-ARD(4) speedup over S-ARD: {:.2}x (paper reports 1.5–2.5x on 4 CPUs)",
        t_seq / t_par4.max(1e-9)
    );
}
