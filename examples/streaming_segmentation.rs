//! End-to-end driver (the repo's headline validation run): solve a
//! volumetric-segmentation mincut that is processed **one region at a
//! time from disk**, exactly the paper's streaming mode, and report the
//! paper's headline metrics — sweeps, disk I/O, and the shared/region
//! memory split — against the whole-graph BK baseline.
//!
//! The paper's Table 1 result this reproduces in shape: S-ARD solves
//! segmentation instances in ~10–20 sweeps with CPU time comparable to
//! BK while holding only one region (plus O(|B|) shared state) in
//! memory; S-PRD needs many more sweeps and proportionally more I/O.
//!
//! ```sh
//! cargo run --release --example streaming_segmentation [SIDE]
//! ```
//! Default SIDE=48 (110k voxels); the paper-scale shape holds at any
//! size. The run is recorded in EXPERIMENTS.md §End-to-end.

use armincut::coordinator::sequential::{solve_sequential, SeqOptions};
use armincut::core::partition::Partition;
use armincut::gen::grid3d::{grid3d_segmentation, Grid3dParams};
use armincut::solvers::{bk::Bk, MaxFlowSolver};
use std::time::Instant;

fn main() {
    let side: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(48);
    // strong n-links relative to the terminals force long augmenting
    // paths across region boundaries — the regime where the sweep count
    // separates ARD from PRD (paper §7.1/Table 1)
    let mut params = Grid3dParams::segmentation(side, 60, 42);
    params.terminal = 40;
    println!("generating {side}x{side}x{side} segmentation volume (6-connected) ...");
    let g = grid3d_segmentation(&params);
    println!(
        "instance: n = {} voxels, m = {} edges, {} MB resident",
        g.n(),
        g.num_arcs() / 2,
        g.memory_bytes() >> 20
    );

    // ---- whole-graph baseline (needs the full graph in memory) --------
    let mut gc = g.clone();
    let t = Instant::now();
    let flow_bk = Bk::new().solve(&mut gc);
    let t_bk = t.elapsed();
    println!("\nBK (whole graph in memory): flow = {flow_bk}, cpu = {:.2}s", t_bk.as_secs_f64());
    drop(gc);

    // ---- streaming S-ARD: 64 regions, one in memory at a time ----------
    let partition = Partition::grid3d(side, side, side, 4, 4, 4);
    let stats = partition.stats(&g);
    println!(
        "\npartition: {} regions, |B| = {} boundary vertices, {} inter-region arcs",
        stats.k, stats.boundary_nodes, stats.inter_region_arcs
    );

    let dir = std::env::temp_dir().join(format!("armincut_stream_{}", std::process::id()));
    let mut sweeps = Vec::new();
    let mut io = Vec::new();
    for (name, mut opts) in [("S-ARD", SeqOptions::ard()), ("S-PRD", SeqOptions::prd())] {
        opts.streaming_dir = Some(dir.clone());
        let res = solve_sequential(&g, &partition, &opts).expect("streaming solve");
        let m = &res.metrics;
        assert!(m.converged, "{name} did not converge");
        assert_eq!(m.flow, flow_bk, "{name} flow must match BK");
        let snap = g.snapshot();
        assert_eq!(g.cut_cost(&snap, &res.cut), flow_bk, "{name} cut certificate");
        println!(
            "\n{name} (streaming, 1 region resident):\n  flow        = {} (matches BK ✓)\n  sweeps      = {} (+{} label-only)\n  cpu         = {:.2}s  (discharge {:.2}s, relabel {:.2}s, gap {:.2}s, msg {:.2}s)\n  disk I/O    = {} MB read, {} MB written ({} MB raw before page compression)\n  disk time   = {:.2}s blocking + {:.2}s overlapped; prefetch {}/{} hits\n  memory      = {:.1} MB shared + {:.1} MB region page (vs {} MB whole graph)",
            m.flow,
            m.sweeps,
            m.extra_sweeps,
            m.cpu().as_secs_f64(),
            m.t_discharge.as_secs_f64(),
            m.t_relabel.as_secs_f64(),
            m.t_gap.as_secs_f64(),
            m.t_msg.as_secs_f64(),
            m.disk_read_bytes >> 20,
            m.disk_write_bytes >> 20,
            m.page_raw_bytes >> 20,
            m.t_disk.as_secs_f64(),
            m.t_disk_overlapped.as_secs_f64(),
            m.prefetch_hits,
            m.prefetch_hits + m.prefetch_misses,
            m.shared_mem_bytes as f64 / (1 << 20) as f64,
            m.max_region_mem_bytes as f64 / (1 << 20) as f64,
            g.memory_bytes() >> 20,
        );
        sweeps.push(m.sweeps);
        io.push(m.disk_read_bytes + m.disk_write_bytes);
    }
    std::fs::remove_dir_all(&dir).ok();
    println!(
        "\nheadline: S-ARD {} sweeps / {} MB I/O vs S-PRD {} sweeps / {} MB I/O",
        sweeps[0],
        io[0] >> 20,
        sweeps[1],
        io[1] >> 20
    );
    println!("resident memory = one region + O(|B|) shared, not the whole graph.");
}
