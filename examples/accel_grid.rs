//! Accelerated region discharge through the three-layer stack: the
//! Pallas lock-step push-relabel kernel (L1), lowered through the JAX
//! wave loop (L2) into `artifacts/grid_pr_*.hlo.txt`, executed from
//! rust via the PJRT CPU client (L3) — the paper's Conclusion item
//! "4) sequential, using GPU for solving region discharge", re-thought
//! for a TPU-shaped kernel (DESIGN.md §Hardware-Adaptation).
//!
//! Requires `make artifacts` first.
//!
//! ```sh
//! cargo run --release --example accel_grid
//! ```

use armincut::core::error::Result;
use armincut::runtime::grid_accel::{GridAccel, GridProblem, TiledAccelCoordinator};
use armincut::runtime::pjrt::PjrtRuntime;
use armincut::solvers::{bk::Bk, MaxFlowSolver};
use std::time::Instant;

fn main() -> Result<()> {
    let dir = std::env::var("ARMINCUT_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let rt = PjrtRuntime::cpu()?;
    println!("PJRT platform: {}", rt.platform());

    // ---- whole-grid solve through the 64x64 artifact -------------------
    let p0 = GridProblem::random(64, 64, 30, 60, 1);
    let expect = Bk::new().solve(&mut p0.to_graph());
    println!("\n64x64 grid, strength 30, ±60 excess; BK flow = {expect}");

    let mut acc = GridAccel::load(&rt, &dir, 64, 64, 32)?;
    let mut p = p0.clone();
    let t = Instant::now();
    let converged = acc.solve(&mut p, 100_000)?;
    println!(
        "kernel (whole grid): flow = {} in {} artifact calls ({} waves), {:.3}s — {}",
        p.flow,
        acc.calls,
        acc.calls as usize * acc.waves_per_call,
        t.elapsed().as_secs_f64(),
        if converged { "converged" } else { "CAPPED" }
    );
    assert_eq!(p.flow, expect);

    let mut p = p0.clone();
    let t = Instant::now();
    p.solve_reference(5_000_000);
    println!("pure-rust waves:     flow = {} in {:.3}s", p.flow, t.elapsed().as_secs_f64());

    // ---- tiled coordinator: 2x2 regions of 32x32 + frozen halo ---------
    let acc34 = GridAccel::load(&rt, &dir, 34, 34, 32)?;
    let mut tc = TiledAccelCoordinator::new(acc34);
    let mut p = p0.clone();
    let t = Instant::now();
    let converged = tc.solve(&mut p, 100_000)?;
    println!(
        "tiled kernel (4 region discharges/sweep): flow = {} in {} sweeps, {} discharges, {:.3}s — {}",
        p.flow,
        tc.sweeps,
        tc.discharges,
        t.elapsed().as_secs_f64(),
        if converged { "converged" } else { "CAPPED" }
    );
    assert_eq!(p.flow, expect);
    println!("\nall three paths agree with BK ✓");
    Ok(())
}
