//! §Perf probe: S-ARD hot-path timing on a paper-style instance.
//!
//! Runs the BK core twice — warm (§6.3 forest reuse across stages, the
//! default) and cold (forests rebuilt every stage, the pre-warm-start
//! baseline) — so the discharge-time delta and the grow/augment/adopt
//! work counters are directly comparable in one invocation:
//!
//! ```sh
//! cargo run --release --example perf_probe           # 500×500
//! cargo run --release --example perf_probe -- 1000   # 1000×1000 (§7.1)
//! ```
use armincut::coordinator::sequential::{solve_sequential, CoreKind, SeqOptions};
use armincut::core::partition::Partition;
use armincut::gen::synthetic2d::{synthetic_2d, Synthetic2dParams};
use armincut::solvers::{bk::Bk, MaxFlowSolver};

fn main() {
    let side: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(500);
    let p = Synthetic2dParams {
        width: side,
        height: side,
        strength: 150,
        seed: 1,
        ..Default::default()
    };
    let g = synthetic_2d(&p);
    let part = Partition::grid2d(side, side, 4, 4);
    println!("n={} m={} |B|={}", g.n(), g.num_arcs() / 2, part.stats(&g).boundary_nodes);

    let t = std::time::Instant::now();
    let f = Bk::new().solve(&mut g.clone());
    println!("BK whole-graph: {:.3}s flow {f}", t.elapsed().as_secs_f64());

    for (name, core, warm) in [
        ("bk-core", CoreKind::Bk, true),
        ("bk-core-cold", CoreKind::Bk, false),
        ("dinic-core", CoreKind::Dinic, true),
    ] {
        let mut o = SeqOptions::ard();
        o.core = core;
        o.warm_start = warm;
        let res = solve_sequential(&g, &part, &o).expect("solve");
        assert_eq!(res.metrics.flow, f);
        println!(
            "S-ARD {name}: total {:.3}s discharge {:.3}s relabel {:.3}s gap {:.3}s \
             msg {:.3}s sweeps {} core g/a/a {}/{}/{}",
            res.metrics.t_total.as_secs_f64(),
            res.metrics.t_discharge.as_secs_f64(),
            res.metrics.t_relabel.as_secs_f64(),
            res.metrics.t_gap.as_secs_f64(),
            res.metrics.t_msg.as_secs_f64(),
            res.metrics.sweeps,
            res.metrics.core_grow,
            res.metrics.core_augment,
            res.metrics.core_adopt
        );
    }
    let res = solve_sequential(&g, &part, &SeqOptions::prd()).expect("solve");
    assert_eq!(res.metrics.flow, f);
    println!(
        "S-PRD: total {:.3}s discharge {:.3}s sweeps {}",
        res.metrics.t_total.as_secs_f64(),
        res.metrics.t_discharge.as_secs_f64(),
        res.metrics.sweeps
    );
}
