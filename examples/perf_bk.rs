//! Count BK work inside ARD stages. With warm starts (the default) the
//! grow/adopt totals drop sharply against `ard.warm_start = false` —
//! the §6.3 forest-reuse win in isolation. Counters are cumulative over
//! the workspace lifetime, so the final print is the 10-sweep total.
use armincut::core::partition::Partition;
use armincut::gen::synthetic2d::{synthetic_2d, Synthetic2dParams};
use armincut::region::ard::{Ard, ArdCore};
use armincut::region::decompose::{Decomposition, DistanceMode};

fn main() {
    let side = 400;
    let p = Synthetic2dParams {
        width: side,
        height: side,
        strength: 150,
        seed: 1,
        ..Default::default()
    };
    let g = synthetic_2d(&p);
    let part = Partition::grid2d(side, side, 4, 4);
    for warm in [true, false] {
        let mut dec = Decomposition::new(&g, &part, DistanceMode::Ard);
        let d_inf = dec.shared.d_inf;
        let mut ard = Ard::new(ArdCore::bk());
        ard.warm_start = warm;
        let t = std::time::Instant::now();
        let mut stages = 0u64;
        for sweep in 0..10 {
            for r in 0..dec.parts.len() {
                dec.sync_in(r);
                let st = ard.discharge(&mut dec.parts[r], d_inf, sweep);
                stages += st.stages as u64;
                dec.sync_out(r);
            }
        }
        let label = if warm { "warm" } else { "cold" };
        println!(
            "10 sweeps bk-core ({label}): {:.3}s, {stages} routing stages",
            t.elapsed().as_secs_f64()
        );
        if let ArdCore::Bk(bk) = &ard.core {
            println!(
                "  augmentations {} grown {} adoptions {}",
                bk.augmentations, bk.grown, bk.adoptions
            );
        }
    }
}
