//! Count BK work inside ARD stages (restart overhead estimate).
use armincut::core::partition::Partition;
use armincut::gen::synthetic2d::{synthetic_2d, Synthetic2dParams};
use armincut::region::ard::{Ard, ArdCore};
use armincut::region::decompose::{Decomposition, DistanceMode};

fn main() {
    let side = 400;
    let p = Synthetic2dParams { width: side, height: side, strength: 150, seed: 1, ..Default::default() };
    let g = synthetic_2d(&p);
    let part = Partition::grid2d(side, side, 4, 4);
    let mut dec = Decomposition::new(&g, &part, DistanceMode::Ard);
    let d_inf = dec.shared.d_inf;
    let mut ard = Ard::new(ArdCore::bk());
    let t = std::time::Instant::now();
    let mut stages = 0u64;
    for sweep in 0..10 {
        for r in 0..dec.parts.len() {
            dec.sync_in(r);
            let st = ard.discharge(&mut dec.parts[r], d_inf, sweep);
            stages += st.stages as u64;
            dec.sync_out(r);
        }
    }
    println!("10 sweeps bk-core: {:.3}s, {stages} stages", t.elapsed().as_secs_f64());
    if let ArdCore::Bk(bk) = &ard.core {
        println!("augmentations {} grown {} adoptions {}", bk.augmentations, bk.adoptions, bk.grown);
    }
}
