//! Quickstart: build a small network with the public API, partition it,
//! solve with S-ARD, and read off the minimum cut.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use armincut::coordinator::sequential::{solve_sequential, SeqOptions};
use armincut::core::graph::GraphBuilder;
use armincut::core::partition::Partition;

fn main() {
    // A 4x3 grid "image": left half prefers the source (foreground),
    // right half the sink (background); n-links are contrast weights.
    let (w, h) = (4usize, 3usize);
    let mut b = GraphBuilder::new(w * h);
    for y in 0..h {
        for x in 0..w {
            let v = (y * w + x) as u32;
            // terminal: + = source supply (foreground), − = sink demand
            b.add_signed_terminal(v, if x < w / 2 { 10 } else { -10 });
            if x + 1 < w {
                // weak link across the middle = the cheap cut
                let cap = if x == w / 2 - 1 { 2 } else { 8 };
                b.add_edge(v, v + 1, cap, cap);
            }
            if y + 1 < h {
                b.add_edge(v, v + w as u32, 8, 8);
            }
        }
    }
    let g = b.build();

    // Two regions (left/right half) — `|B|` is the 2·h middle column.
    let partition = Partition::grid2d(w, h, 2, 1);

    let result = solve_sequential(&g, &partition, &SeqOptions::ard()).expect("solve");
    println!("max flow / min cut value: {}", result.metrics.flow);
    println!(
        "solved in {} sweeps (+{} label-only), {} region discharges",
        result.metrics.sweeps, result.metrics.extra_sweeps, result.metrics.discharges
    );

    // the cut: `true` = sink side
    for y in 0..h {
        let row: String = (0..w)
            .map(|x| if result.cut[y * w + x] { 'B' } else { 'F' })
            .collect();
        println!("{row}");
    }

    // the cut is a certificate: its cost equals the flow value
    let snap = g.snapshot();
    assert_eq!(g.cut_cost(&snap, &result.cut), result.metrics.flow);
    println!("certificate OK (cut cost == flow)");
}
