//! Expansion-move energy minimization — the application class that
//! motivates the paper ("Expansion-move, swap-move and fusion-move
//! algorithms formulate a local improvement step as a MINCUT problem",
//! §1). A multi-label Potts MRF over an image grid is minimized by
//! α-expansion; **every expansion step is a mincut solved by the
//! distributed S-ARD coordinator**, exactly how the paper's BVZ stereo
//! instances arise (sequences of expansion subproblems, Table 1
//! "stereo: sequences of subproblems … for which the total time should
//! be reported").
//!
//! ```sh
//! cargo run --release --example expansion_move [WIDTH HEIGHT LABELS]
//! ```

use armincut::coordinator::sequential::{solve_sequential, SeqOptions};
use armincut::core::graph::{Cap, GraphBuilder};
use armincut::core::partition::Partition;
use armincut::core::prng::Rng;

/// Potts energy: Σ_p D_p(x_p) + λ Σ_{pq} [x_p ≠ x_q].
struct Mrf {
    w: usize,
    h: usize,
    labels: usize,
    /// unary costs, `data[p * labels + l]`
    data: Vec<Cap>,
    lambda: Cap,
}

impl Mrf {
    /// A noisy piecewise-constant image: ground-truth label patches plus
    /// unary noise (the classic denoising/segmentation setup).
    fn synthetic(w: usize, h: usize, labels: usize, seed: u64) -> Mrf {
        let mut rng = Rng::new(seed);
        // random smooth ground truth: nearest of `labels` seed points
        let seeds: Vec<(f64, f64)> =
            (0..labels).map(|_| (rng.f64() * w as f64, rng.f64() * h as f64)).collect();
        let mut data = vec![0 as Cap; w * h * labels];
        for y in 0..h {
            for x in 0..w {
                let p = y * w + x;
                let truth = seeds
                    .iter()
                    .enumerate()
                    .min_by(|(_, a), (_, b)| {
                        let da = (a.0 - x as f64).powi(2) + (a.1 - y as f64).powi(2);
                        let db = (b.0 - x as f64).powi(2) + (b.1 - y as f64).powi(2);
                        da.partial_cmp(&db).unwrap()
                    })
                    .unwrap()
                    .0;
                for l in 0..labels {
                    // noise wider than the truth gap → the unary argmin
                    // is wrong on a sizeable fraction of pixels and the
                    // expansion moves have real smoothing work to do
                    let base = if l == truth { 0 } else { 30 };
                    data[p * labels + l] = base + rng.range_i64(0, 60);
                }
            }
        }
        Mrf { w, h, labels, data, lambda: 14 }
    }

    fn unary(&self, p: usize, l: usize) -> Cap {
        self.data[p * self.labels + l]
    }

    fn energy(&self, x: &[usize]) -> Cap {
        let mut e = 0;
        for p in 0..self.w * self.h {
            e += self.unary(p, x[p]);
        }
        for y in 0..self.h {
            for xx in 0..self.w {
                let p = y * self.w + xx;
                if xx + 1 < self.w && x[p] != x[p + 1] {
                    e += self.lambda;
                }
                if y + 1 < self.h && x[p] != x[p + self.w] {
                    e += self.lambda;
                }
            }
        }
        e
    }

    /// One α-expansion: build the binary subproblem (keep current label
    /// vs switch to α) and solve it with the distributed coordinator.
    /// For the Potts model the construction is submodular: cut side
    /// `true` (sink, `T`) = keep the current label, `false` = take α.
    fn expand(&self, x: &mut [usize], alpha: usize, opts: &SeqOptions, regions: usize) -> bool {
        let n = self.w * self.h;
        let mut b = GraphBuilder::new(n);
        for p in 0..n {
            // source arc = cost of keeping x_p (paid when p ∈ T... we use
            // the convention: excess = cost(keep), sink cap = cost(α))
            if x[p] == alpha {
                // switching is a no-op; bias hard toward keep (= α here)
                b.add_terminal(p as u32, self.unary(p, alpha), 0);
                continue;
            }
            b.add_terminal(p as u32, self.unary(p, x[p]), self.unary(p, alpha));
            let _ = p;
        }
        // pairwise Potts terms, standard submodular decomposition
        // (Kolmogorov–Zabih): with z = 1 ⇔ keep (sink side T),
        //   E(z_p, z_q) = e00 + (e10−e00)·z_p + (e11−e10)·z_q
        //               + θ·(1−z_p)·z_q,   θ = e01 + e10 − e00 − e11 ≥ 0,
        // where the θ term is an arc p→q (cut when p ∈ S takes α while
        // q ∈ T keeps) and positive z-coefficients become excess (paid on
        // the T side), negative ones sink capacity (paid on the S side).
        let mut add_pair = |b: &mut GraphBuilder, p: usize, q: usize| {
            let (xp, xq) = (x[p], x[q]);
            let e00 = 0 as Cap; // both take α
            let e01 = self.lambda * ((alpha != xq) as Cap);
            let e10 = self.lambda * ((xp != alpha) as Cap);
            let e11 = self.lambda * ((xp != xq) as Cap);
            let wp = e10 - e00;
            let wq = e11 - e10;
            b.add_terminal(p as u32, wp.max(0), (-wp).max(0));
            b.add_terminal(q as u32, wq.max(0), (-wq).max(0));
            let theta = e01 + e10 - e00 - e11;
            debug_assert!(theta >= 0, "Potts expansion is submodular");
            if theta > 0 {
                b.add_edge(p as u32, q as u32, theta, 0);
            }
        };
        for y in 0..self.h {
            for xx in 0..self.w {
                let p = y * self.w + xx;
                if xx + 1 < self.w {
                    add_pair(&mut b, p, p + 1);
                }
                if y + 1 < self.h {
                    add_pair(&mut b, p, p + self.w);
                }
            }
        }
        let g = b.build();
        let partition = Partition::by_node_ranges(n, regions);
        let res = solve_sequential(&g, &partition, opts).expect("solve");
        assert!(res.metrics.converged);
        // cut side true (T, sink) = "keep current"; false (S) = take α
        let before = self.energy(x);
        let mut changed = false;
        let backup: Vec<usize> = x.to_vec();
        for p in 0..n {
            if !res.cut[p] && x[p] != alpha {
                x[p] = alpha;
                changed = true;
            }
        }
        let switched = x.iter().zip(&backup).filter(|(a, b)| a != b).count();
        let after = self.energy(x);
        if std::env::var("EXPANSION_DEBUG").is_ok() {
            eprintln!("  expand(α={alpha}): switched {switched}, energy {before} -> {after}");
        }
        if after > before {
            // the move must never increase the energy — solver certificate
            x.copy_from_slice(&backup);
            panic!("expansion increased energy: {before} -> {after}");
        }
        changed && after < before
    }
}

fn main() {
    let mut args = std::env::args().skip(1);
    let w: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(120);
    let h: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(90);
    let labels: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(5);
    let mrf = Mrf::synthetic(w, h, labels, 7);
    println!("Potts MRF {w}x{h}, {labels} labels, λ = {}", mrf.lambda);

    // init: per-pixel best unary
    let n = w * h;
    let mut x: Vec<usize> =
        (0..n).map(|p| (0..labels).min_by_key(|&l| mrf.unary(p, l)).unwrap()).collect();
    println!("initial energy (unary argmin): {}", mrf.energy(&x));

    let opts = SeqOptions::ard();
    let t = std::time::Instant::now();
    let mut cuts = 0;
    for round in 0..4 {
        let mut improved = false;
        for alpha in 0..labels {
            improved |= mrf.expand(&mut x, alpha, &opts, 8);
            cuts += 1;
        }
        println!("after round {}: energy {}", round + 1, mrf.energy(&x));
        if !improved {
            break;
        }
    }
    println!(
        "converged: energy {} after {cuts} mincut subproblems (S-ARD, 8 regions each) in {:.2}s",
        mrf.energy(&x),
        t.elapsed().as_secs_f64()
    );
    // label histogram sanity
    let mut hist = vec![0usize; labels];
    for &l in &x {
        hist[l] += 1;
    }
    println!("label histogram: {hist:?}");
}
