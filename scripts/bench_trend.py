#!/usr/bin/env python3
"""Diff two directories of BENCH_<id>.json bench records.

First move on the ROADMAP "track BENCH_*.json across merges" item: the
CI bench-smoke job keeps the previous run's records as a rolling
baseline and runs this script against the fresh ones.

For every (bench, case, solver) record present in both directories:

* ``flow`` MUST match — a flow drift is a correctness regression and
  makes the script exit 1;
* ``wall_seconds``, the disk-byte fields (schema 3:
  ``page_stored_bytes``, ``page_raw_bytes``), the distributed wire
  fields (schema 4: ``wire_bytes_sent``/``recv``), the parallel-sweep
  fields (schema 5: ``dist_batches``, ``max_inflight_discharges``,
  ``par_sweep_seconds``) and the fault-tolerance fields (schema 6:
  ``worker_restarts``, ``checkpoint_bytes``, ``recovery_wall_seconds``;
  older schemas fall back to zero) are reported as deltas or carried in
  the history — advisory only, machines differ.

With ``--plot DIR`` the script renders the ``--history`` file as SVG
trend curves (wall time, page bytes, wire bytes, sync time, worker
restarts — one file per tracked quantity, one colored line per (bench,
case, solver) series). Pure stdlib; CI uploads the directory as an
artifact next to the history.

With ``--history FILE`` the script additionally maintains a rolling
multi-run history: one JSON line per run (condensed records: flow,
wall, page bytes, wire bytes, sync time), trimmed to the last
``--history-max`` runs. CI keeps the file in a cache and uploads it as
an artifact, so the perf trajectory survives across merges instead of
only ever comparing two adjacent runs.

Incoming records are validated against ``scripts/schema_fields.json``,
the machine-readable record schema emitted by ``armincut analyze
--emit-schema`` (schema version + field list + history fields). A
current-run record with a missing or unknown field, or a document with
the wrong schema stamp, is drift between the Rust writer and this
consumer and makes the script exit 1. Baseline records are exempt —
they may legitimately predate a schema bump.

No baseline directory (first run) is not an error: the script reports
it and exits 0. Stdlib only.

Usage:
    bench_trend.py CURRENT_DIR BASELINE_DIR [--wall-warn-pct 25]
                   [--history FILE] [--history-max 50] [--run-label L]
                   [--schema FILE] [--plot DIR]
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
from pathlib import Path

#: Condensed per-record fields kept in the multi-run history (missing
#: fields — older schemas — default to 0).
HISTORY_FIELDS = (
    "flow",
    "wall_seconds",
    "page_raw_bytes",
    "page_stored_bytes",
    "wire_bytes_sent",
    "wire_bytes_recv",
    "wire_raw_bytes",
    "sync_wall_seconds",
    "dist_batches",
    "max_inflight_discharges",
    "par_sweep_seconds",
    "worker_restarts",
    "checkpoint_bytes",
    "recovery_wall_seconds",
    "trace_events",
    "trace_dropped",
    "discharge_seconds",
    "fuse_seconds",
)

#: Curves rendered by ``--plot DIR``: (record field, axis label). The
#: pseudo-field ``wire_bytes`` is the sent+recv sum.
PLOT_SERIES = (
    ("wall_seconds", "wall time (s)"),
    ("page_stored_bytes", "page bytes (stored)"),
    ("wire_bytes", "wire bytes (sent+recv)"),
    ("sync_wall_seconds", "sync time (s)"),
    ("worker_restarts", "worker restarts"),
)

#: Line colors cycled across the per-(bench, case, solver) series.
PALETTE = ("#1f77b4", "#d62728", "#2ca02c", "#9467bd", "#ff7f0e",
           "#8c564b", "#17becf", "#7f7f7f")


#: Default location of the emitted schema, next to this script.
SCHEMA_FILE = Path(__file__).resolve().parent / "schema_fields.json"


def validate_records(current: dict[str, dict], schema: dict) -> list[str]:
    """Check every current-run record against the emitted schema.
    Returns human-readable problem lines (empty = clean)."""
    problems = []
    want_version = schema.get("schema")
    fields = set(schema.get("fields", []))
    for bench_id in sorted(current):
        doc = current[bench_id]
        if doc.get("schema") != want_version:
            problems.append(
                f"{bench_id}: schema {doc.get('schema')} != expected "
                f"{want_version} (stale armincut or stale schema_fields.json?)"
            )
        for rec in doc.get("records", []):
            key = f"{bench_id} {rec.get('case', '?')} {rec.get('solver', '?')}"
            missing = sorted(fields - set(rec))
            unknown = sorted(set(rec) - fields)
            if missing:
                problems.append(f"{key}: record is missing {', '.join(missing)}")
            if unknown:
                problems.append(
                    f"{key}: record has unknown field(s) {', '.join(unknown)} — "
                    f"rerun `armincut analyze --emit-schema` and commit the result"
                )
    return problems


def load_dir(path: Path) -> dict[str, dict]:
    """Map bench id -> parsed BENCH_<id>.json for every file in `path`."""
    out = {}
    for f in sorted(path.glob("BENCH_*.json")):
        bench_id = f.stem[len("BENCH_"):]
        try:
            out[bench_id] = json.loads(f.read_text())
        except (OSError, json.JSONDecodeError) as e:
            print(f"warning: skipping unreadable {f.name}: {e}")
    return out


def record_key(rec: dict) -> tuple[str, str]:
    return (rec.get("case", "?"), rec.get("solver", "?"))


def index_records(doc: dict) -> dict[tuple[str, str], dict]:
    return {record_key(r): r for r in doc.get("records", [])}


def fmt_delta(cur: float, base: float, unit: str = "") -> str:
    if base == 0:
        return f"{cur:g}{unit} (new)" if cur else "0 -> 0"
    pct = 100.0 * (cur - base) / base
    return f"{base:g}{unit} -> {cur:g}{unit} ({pct:+.1f}%)"


def compare(current: dict[str, dict], baseline: dict[str, dict],
            wall_warn_pct: float) -> tuple[int, int]:
    """Print the trend report. Returns (flow_mismatches, compared)."""
    mismatches = 0
    compared = 0
    for bench_id in sorted(current):
        if bench_id not in baseline:
            print(f"{bench_id}: no baseline record, skipping")
            continue
        cur = index_records(current[bench_id])
        base = index_records(baseline[bench_id])
        for key in sorted(cur):
            if key not in base:
                print(f"{bench_id} {key}: new record (no baseline)")
                continue
            c, b = cur[key], base[key]
            compared += 1
            case, solver = key
            if c.get("flow") != b.get("flow"):
                mismatches += 1
                print(
                    f"{bench_id} {case} {solver}: FLOW MISMATCH "
                    f"{b.get('flow')} -> {c.get('flow')}"
                )
                continue
            cw = float(c.get("wall_seconds", 0.0))
            bw = float(b.get("wall_seconds", 0.0))
            marker = ""
            if bw > 0 and cw > bw * (1 + wall_warn_pct / 100.0):
                marker = "  [slower]"
            elif bw > 0 and cw < bw * (1 - wall_warn_pct / 100.0):
                marker = "  [faster]"
            disk = ""
            stored_c = int(c.get("page_stored_bytes", 0))
            stored_b = int(b.get("page_stored_bytes", 0))
            if stored_c or stored_b:
                disk = f", pages {fmt_delta(stored_c, stored_b, 'B')}"
            wire = ""
            wire_c = int(c.get("wire_bytes_sent", 0)) + int(c.get("wire_bytes_recv", 0))
            wire_b = int(b.get("wire_bytes_sent", 0)) + int(b.get("wire_bytes_recv", 0))
            if wire_c or wire_b:
                wire = f", wire {fmt_delta(wire_c, wire_b, 'B')}"
            rest = ""
            rest_c = int(c.get("worker_restarts", 0))
            rest_b = int(b.get("worker_restarts", 0))
            if rest_c or rest_b:
                rest = f", restarts {rest_b} -> {rest_c}"
            print(
                f"{bench_id} {case} {solver}: "
                f"wall {fmt_delta(cw, bw, 's')}{disk}{wire}{rest}{marker}"
            )
        for key in sorted(set(base) - set(cur)):
            print(f"{bench_id} {key}: record disappeared from current run")
    return mismatches, compared


def append_history(path: Path, label: str, current: dict[str, dict],
                   max_runs: int) -> int:
    """Append one condensed line for this run to the rolling history at
    `path` (JSON Lines, oldest first), trimming to `max_runs` lines.
    Returns the number of runs now tracked."""
    records = []
    for bench_id in sorted(current):
        for r in current[bench_id].get("records", []):
            entry = {"bench": bench_id, "case": r.get("case", "?"),
                     "solver": r.get("solver", "?")}
            for f in HISTORY_FIELDS:
                entry[f] = r.get(f, 0)
            records.append(entry)
    line = json.dumps({"run": label, "time": int(time.time()),
                       "records": records}, sort_keys=True)
    path.parent.mkdir(parents=True, exist_ok=True)
    lines: list[str] = []
    if path.is_file():
        for old in path.read_text().splitlines():
            old = old.strip()
            if not old:
                continue
            try:
                json.loads(old)
            except json.JSONDecodeError:
                continue  # drop corrupt lines instead of carrying them
            lines.append(old)
    lines.append(line)
    lines = lines[-max(max_runs, 1):]
    path.write_text("\n".join(lines) + "\n")
    return len(lines)


def history_runs(path: Path) -> list[dict]:
    """Parse the rolling history written by ``append_history`` (JSON
    lines, oldest first), skipping blank or corrupt lines."""
    runs: list[dict] = []
    if not path.is_file():
        return runs
    for line in path.read_text().splitlines():
        line = line.strip()
        if not line:
            continue
        try:
            runs.append(json.loads(line))
        except json.JSONDecodeError:
            continue
    return runs


def series_value(rec: dict, field: str) -> float:
    """One plotted value of a condensed history record."""
    if field == "wire_bytes":
        return (float(rec.get("wire_bytes_sent", 0))
                + float(rec.get("wire_bytes_recv", 0)))
    return float(rec.get(field, 0))


def collect_series(runs: list[dict], field: str) -> dict[str, list[tuple[int, float]]]:
    """``"bench case solver" -> [(run_index, value), ...]`` across runs.
    A record absent from some run simply leaves a gap in its series."""
    out: dict[str, list[tuple[int, float]]] = {}
    for i, run in enumerate(runs):
        for rec in run.get("records", []):
            key = (f"{rec.get('bench', '?')} {rec.get('case', '?')} "
                   f"{rec.get('solver', '?')}")
            out.setdefault(key, []).append((i, series_value(rec, field)))
    return out


def _xml_escape(s: str) -> str:
    return s.replace("&", "&amp;").replace("<", "&lt;").replace(">", "&gt;")


def svg_plot(title: str, n_runs: int,
             series: dict[str, list[tuple[int, float]]],
             width: int = 720, height: int = 360) -> str:
    """Render one trend chart as a standalone SVG document: the runs on
    the x axis (oldest left), values on the y axis scaled to the series
    maximum, one polyline + point markers + legend row per series."""
    ml, mr, mt, mb = 64, 12, 28, 28
    pw, ph = width - ml - mr, height - mt - mb
    vmax = max((v for pts in series.values() for _, v in pts), default=0.0)
    if vmax <= 0:
        vmax = 1.0

    def x(i: int) -> float:
        return ml + pw * i / max(n_runs - 1, 1)

    def y(v: float) -> float:
        return mt + ph - ph * v / vmax

    parts = [
        f'<svg xmlns="http://www.w3.org/2000/svg" width="{width}" '
        f'height="{height}" font-family="monospace" font-size="11">',
        f'<rect width="{width}" height="{height}" fill="white"/>',
        f'<text x="{ml}" y="17" font-size="13">{_xml_escape(title)}</text>',
        f'<line x1="{ml}" y1="{mt}" x2="{ml}" y2="{mt + ph}" stroke="black"/>',
        f'<line x1="{ml}" y1="{mt + ph}" x2="{ml + pw}" y2="{mt + ph}" '
        f'stroke="black"/>',
        f'<text x="4" y="{mt + 9}">{vmax:g}</text>',
        f'<text x="4" y="{mt + ph}">0</text>',
        f'<text x="{ml}" y="{height - 8}">run 1</text>',
        f'<text x="{ml + pw - 56}" y="{height - 8}">run {n_runs}</text>',
    ]
    for si, key in enumerate(sorted(series)):
        color = PALETTE[si % len(PALETTE)]
        pts = series[key]
        coords = " ".join(f"{x(i):.1f},{y(v):.1f}" for i, v in pts)
        parts.append(f'<polyline points="{coords}" fill="none" '
                     f'stroke="{color}" stroke-width="1.5"/>')
        for i, v in pts:
            parts.append(f'<circle cx="{x(i):.1f}" cy="{y(v):.1f}" r="2.5" '
                         f'fill="{color}"/>')
        ly = mt + 14 + 13 * si
        parts.append(f'<rect x="{ml + pw - 300}" y="{ly - 9}" width="10" '
                     f'height="10" fill="{color}"/>')
        parts.append(f'<text x="{ml + pw - 286}" y="{ly}">'
                     f'{_xml_escape(key)}</text>')
    parts.append("</svg>")
    return "\n".join(parts) + "\n"


def write_plots(runs: list[dict], out_dir: Path) -> list[Path]:
    """Render one ``trend_<field>.svg`` per PLOT_SERIES entry whose data
    is not identically zero. Returns the files written."""
    out_dir.mkdir(parents=True, exist_ok=True)
    written = []
    for field, label in PLOT_SERIES:
        series = collect_series(runs, field)
        series = {k: pts for k, pts in series.items()
                  if any(v for _, v in pts)}
        if not series:
            continue
        path = out_dir / f"trend_{field}.svg"
        path.write_text(svg_plot(label, len(runs), series))
        written.append(path)
    return written


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("current", type=Path, help="fresh bench_results dir")
    ap.add_argument("baseline", type=Path, help="previous run's dir")
    ap.add_argument("--wall-warn-pct", type=float, default=25.0,
                    help="flag wall-time moves beyond this percentage")
    ap.add_argument("--history", type=Path, default=None,
                    help="rolling multi-run history file (JSON lines)")
    ap.add_argument("--history-max", type=int, default=50,
                    help="keep at most this many runs in --history")
    ap.add_argument("--run-label", default=None,
                    help="label of this run in the history "
                         "(default: $GITHUB_RUN_ID or 'local')")
    ap.add_argument("--schema", type=Path, default=SCHEMA_FILE,
                    help="schema_fields.json emitted by "
                         "`armincut analyze --emit-schema`")
    ap.add_argument("--plot", type=Path, default=None, metavar="DIR",
                    help="render the --history file as SVG trend curves "
                         "into DIR (stdlib only)")
    args = ap.parse_args(argv)

    if args.plot is not None and args.history is None:
        print("error: --plot needs --history FILE (the curves render from it)")
        return 2

    if not args.current.is_dir():
        print(f"error: current dir {args.current} does not exist")
        return 2
    current = load_dir(args.current)
    if not current:
        print(f"error: no BENCH_*.json in {args.current}")
        return 2
    if args.schema.is_file():
        try:
            schema = json.loads(args.schema.read_text())
        except (OSError, json.JSONDecodeError) as e:
            print(f"error: unreadable schema {args.schema}: {e}")
            return 2
        problems = validate_records(current, schema)
        if problems:
            for p in problems:
                print(f"schema drift: {p}")
            print(f"\n{len(problems)} schema problem(s) in the current run")
            return 1
    else:
        print(f"warning: no record schema at {args.schema}, skipping validation")
    if args.history is not None:
        label = args.run_label or os.environ.get("GITHUB_RUN_ID", "local")
        runs = append_history(args.history, label, current, args.history_max)
        print(f"history: {runs} run(s) tracked in {args.history}")
        if args.plot is not None:
            written = write_plots(history_runs(args.history), args.plot)
            print(f"plot: {len(written)} SVG curve(s) in {args.plot}")
    if not args.baseline.is_dir():
        print(f"no baseline at {args.baseline} (first run?) — nothing to diff")
        return 0
    baseline = load_dir(args.baseline)
    if not baseline:
        print(f"baseline {args.baseline} holds no BENCH_*.json — nothing to diff")
        return 0

    mismatches, compared = compare(current, baseline, args.wall_warn_pct)
    print(f"\ncompared {compared} records, {mismatches} flow mismatch(es)")
    return 1 if mismatches else 0


if __name__ == "__main__":
    sys.exit(main())
