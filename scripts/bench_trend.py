#!/usr/bin/env python3
"""Diff two directories of BENCH_<id>.json bench records.

First move on the ROADMAP "track BENCH_*.json across merges" item: the
CI bench-smoke job keeps the previous run's records as a rolling
baseline and runs this script against the fresh ones.

For every (bench, case, solver) record present in both directories:

* ``flow`` MUST match — a flow drift is a correctness regression and
  makes the script exit 1;
* ``wall_seconds`` and the disk-byte fields (schema 3:
  ``page_stored_bytes``, ``page_raw_bytes``; older schemas fall back to
  zero) are reported as deltas — advisory only, machines differ.

No baseline directory (first run) is not an error: the script reports
it and exits 0. Stdlib only.

Usage:
    bench_trend.py CURRENT_DIR BASELINE_DIR [--wall-warn-pct 25]
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path


def load_dir(path: Path) -> dict[str, dict]:
    """Map bench id -> parsed BENCH_<id>.json for every file in `path`."""
    out = {}
    for f in sorted(path.glob("BENCH_*.json")):
        bench_id = f.stem[len("BENCH_"):]
        try:
            out[bench_id] = json.loads(f.read_text())
        except (OSError, json.JSONDecodeError) as e:
            print(f"warning: skipping unreadable {f.name}: {e}")
    return out


def record_key(rec: dict) -> tuple[str, str]:
    return (rec.get("case", "?"), rec.get("solver", "?"))


def index_records(doc: dict) -> dict[tuple[str, str], dict]:
    return {record_key(r): r for r in doc.get("records", [])}


def fmt_delta(cur: float, base: float, unit: str = "") -> str:
    if base == 0:
        return f"{cur:g}{unit} (new)" if cur else "0 -> 0"
    pct = 100.0 * (cur - base) / base
    return f"{base:g}{unit} -> {cur:g}{unit} ({pct:+.1f}%)"


def compare(current: dict[str, dict], baseline: dict[str, dict],
            wall_warn_pct: float) -> tuple[int, int]:
    """Print the trend report. Returns (flow_mismatches, compared)."""
    mismatches = 0
    compared = 0
    for bench_id in sorted(current):
        if bench_id not in baseline:
            print(f"{bench_id}: no baseline record, skipping")
            continue
        cur = index_records(current[bench_id])
        base = index_records(baseline[bench_id])
        for key in sorted(cur):
            if key not in base:
                print(f"{bench_id} {key}: new record (no baseline)")
                continue
            c, b = cur[key], base[key]
            compared += 1
            case, solver = key
            if c.get("flow") != b.get("flow"):
                mismatches += 1
                print(
                    f"{bench_id} {case} {solver}: FLOW MISMATCH "
                    f"{b.get('flow')} -> {c.get('flow')}"
                )
                continue
            cw = float(c.get("wall_seconds", 0.0))
            bw = float(b.get("wall_seconds", 0.0))
            marker = ""
            if bw > 0 and cw > bw * (1 + wall_warn_pct / 100.0):
                marker = "  [slower]"
            elif bw > 0 and cw < bw * (1 - wall_warn_pct / 100.0):
                marker = "  [faster]"
            disk = ""
            stored_c = int(c.get("page_stored_bytes", 0))
            stored_b = int(b.get("page_stored_bytes", 0))
            if stored_c or stored_b:
                disk = f", pages {fmt_delta(stored_c, stored_b, 'B')}"
            print(
                f"{bench_id} {case} {solver}: "
                f"wall {fmt_delta(cw, bw, 's')}{disk}{marker}"
            )
        for key in sorted(set(base) - set(cur)):
            print(f"{bench_id} {key}: record disappeared from current run")
    return mismatches, compared


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("current", type=Path, help="fresh bench_results dir")
    ap.add_argument("baseline", type=Path, help="previous run's dir")
    ap.add_argument("--wall-warn-pct", type=float, default=25.0,
                    help="flag wall-time moves beyond this percentage")
    args = ap.parse_args(argv)

    if not args.current.is_dir():
        print(f"error: current dir {args.current} does not exist")
        return 2
    current = load_dir(args.current)
    if not current:
        print(f"error: no BENCH_*.json in {args.current}")
        return 2
    if not args.baseline.is_dir():
        print(f"no baseline at {args.baseline} (first run?) — nothing to diff")
        return 0
    baseline = load_dir(args.baseline)
    if not baseline:
        print(f"baseline {args.baseline} holds no BENCH_*.json — nothing to diff")
        return 0

    mismatches, compared = compare(current, baseline, args.wall_warn_pct)
    print(f"\ncompared {compared} records, {mismatches} flow mismatch(es)")
    return 1 if mismatches else 0


if __name__ == "__main__":
    sys.exit(main())
