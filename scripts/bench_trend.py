#!/usr/bin/env python3
"""Diff two directories of BENCH_<id>.json bench records.

First move on the ROADMAP "track BENCH_*.json across merges" item: the
CI bench-smoke job keeps the previous run's records as a rolling
baseline and runs this script against the fresh ones.

For every (bench, case, solver) record present in both directories:

* ``flow`` MUST match — a flow drift is a correctness regression and
  makes the script exit 1;
* ``wall_seconds``, the disk-byte fields (schema 3:
  ``page_stored_bytes``, ``page_raw_bytes``), the distributed wire
  fields (schema 4: ``wire_bytes_sent``/``recv``), the parallel-sweep
  fields (schema 5: ``dist_batches``, ``max_inflight_discharges``,
  ``par_sweep_seconds``) and the fault-tolerance fields (schema 6:
  ``worker_restarts``, ``checkpoint_bytes``, ``recovery_wall_seconds``;
  older schemas fall back to zero) are reported as deltas or carried in
  the history — advisory only, machines differ.

With ``--history FILE`` the script additionally maintains a rolling
multi-run history: one JSON line per run (condensed records: flow,
wall, page bytes, wire bytes, sync time), trimmed to the last
``--history-max`` runs. CI keeps the file in a cache and uploads it as
an artifact, so the perf trajectory survives across merges instead of
only ever comparing two adjacent runs.

Incoming records are validated against ``scripts/schema_fields.json``,
the machine-readable record schema emitted by ``armincut analyze
--emit-schema`` (schema version + field list + history fields). A
current-run record with a missing or unknown field, or a document with
the wrong schema stamp, is drift between the Rust writer and this
consumer and makes the script exit 1. Baseline records are exempt —
they may legitimately predate a schema bump.

No baseline directory (first run) is not an error: the script reports
it and exits 0. Stdlib only.

Usage:
    bench_trend.py CURRENT_DIR BASELINE_DIR [--wall-warn-pct 25]
                   [--history FILE] [--history-max 50] [--run-label L]
                   [--schema FILE]
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
from pathlib import Path

#: Condensed per-record fields kept in the multi-run history (missing
#: fields — older schemas — default to 0).
HISTORY_FIELDS = (
    "flow",
    "wall_seconds",
    "page_raw_bytes",
    "page_stored_bytes",
    "wire_bytes_sent",
    "wire_bytes_recv",
    "wire_raw_bytes",
    "sync_wall_seconds",
    "dist_batches",
    "max_inflight_discharges",
    "par_sweep_seconds",
    "worker_restarts",
    "checkpoint_bytes",
    "recovery_wall_seconds",
)


#: Default location of the emitted schema, next to this script.
SCHEMA_FILE = Path(__file__).resolve().parent / "schema_fields.json"


def validate_records(current: dict[str, dict], schema: dict) -> list[str]:
    """Check every current-run record against the emitted schema.
    Returns human-readable problem lines (empty = clean)."""
    problems = []
    want_version = schema.get("schema")
    fields = set(schema.get("fields", []))
    for bench_id in sorted(current):
        doc = current[bench_id]
        if doc.get("schema") != want_version:
            problems.append(
                f"{bench_id}: schema {doc.get('schema')} != expected "
                f"{want_version} (stale armincut or stale schema_fields.json?)"
            )
        for rec in doc.get("records", []):
            key = f"{bench_id} {rec.get('case', '?')} {rec.get('solver', '?')}"
            missing = sorted(fields - set(rec))
            unknown = sorted(set(rec) - fields)
            if missing:
                problems.append(f"{key}: record is missing {', '.join(missing)}")
            if unknown:
                problems.append(
                    f"{key}: record has unknown field(s) {', '.join(unknown)} — "
                    f"rerun `armincut analyze --emit-schema` and commit the result"
                )
    return problems


def load_dir(path: Path) -> dict[str, dict]:
    """Map bench id -> parsed BENCH_<id>.json for every file in `path`."""
    out = {}
    for f in sorted(path.glob("BENCH_*.json")):
        bench_id = f.stem[len("BENCH_"):]
        try:
            out[bench_id] = json.loads(f.read_text())
        except (OSError, json.JSONDecodeError) as e:
            print(f"warning: skipping unreadable {f.name}: {e}")
    return out


def record_key(rec: dict) -> tuple[str, str]:
    return (rec.get("case", "?"), rec.get("solver", "?"))


def index_records(doc: dict) -> dict[tuple[str, str], dict]:
    return {record_key(r): r for r in doc.get("records", [])}


def fmt_delta(cur: float, base: float, unit: str = "") -> str:
    if base == 0:
        return f"{cur:g}{unit} (new)" if cur else "0 -> 0"
    pct = 100.0 * (cur - base) / base
    return f"{base:g}{unit} -> {cur:g}{unit} ({pct:+.1f}%)"


def compare(current: dict[str, dict], baseline: dict[str, dict],
            wall_warn_pct: float) -> tuple[int, int]:
    """Print the trend report. Returns (flow_mismatches, compared)."""
    mismatches = 0
    compared = 0
    for bench_id in sorted(current):
        if bench_id not in baseline:
            print(f"{bench_id}: no baseline record, skipping")
            continue
        cur = index_records(current[bench_id])
        base = index_records(baseline[bench_id])
        for key in sorted(cur):
            if key not in base:
                print(f"{bench_id} {key}: new record (no baseline)")
                continue
            c, b = cur[key], base[key]
            compared += 1
            case, solver = key
            if c.get("flow") != b.get("flow"):
                mismatches += 1
                print(
                    f"{bench_id} {case} {solver}: FLOW MISMATCH "
                    f"{b.get('flow')} -> {c.get('flow')}"
                )
                continue
            cw = float(c.get("wall_seconds", 0.0))
            bw = float(b.get("wall_seconds", 0.0))
            marker = ""
            if bw > 0 and cw > bw * (1 + wall_warn_pct / 100.0):
                marker = "  [slower]"
            elif bw > 0 and cw < bw * (1 - wall_warn_pct / 100.0):
                marker = "  [faster]"
            disk = ""
            stored_c = int(c.get("page_stored_bytes", 0))
            stored_b = int(b.get("page_stored_bytes", 0))
            if stored_c or stored_b:
                disk = f", pages {fmt_delta(stored_c, stored_b, 'B')}"
            wire = ""
            wire_c = int(c.get("wire_bytes_sent", 0)) + int(c.get("wire_bytes_recv", 0))
            wire_b = int(b.get("wire_bytes_sent", 0)) + int(b.get("wire_bytes_recv", 0))
            if wire_c or wire_b:
                wire = f", wire {fmt_delta(wire_c, wire_b, 'B')}"
            rest = ""
            rest_c = int(c.get("worker_restarts", 0))
            rest_b = int(b.get("worker_restarts", 0))
            if rest_c or rest_b:
                rest = f", restarts {rest_b} -> {rest_c}"
            print(
                f"{bench_id} {case} {solver}: "
                f"wall {fmt_delta(cw, bw, 's')}{disk}{wire}{rest}{marker}"
            )
        for key in sorted(set(base) - set(cur)):
            print(f"{bench_id} {key}: record disappeared from current run")
    return mismatches, compared


def append_history(path: Path, label: str, current: dict[str, dict],
                   max_runs: int) -> int:
    """Append one condensed line for this run to the rolling history at
    `path` (JSON Lines, oldest first), trimming to `max_runs` lines.
    Returns the number of runs now tracked."""
    records = []
    for bench_id in sorted(current):
        for r in current[bench_id].get("records", []):
            entry = {"bench": bench_id, "case": r.get("case", "?"),
                     "solver": r.get("solver", "?")}
            for f in HISTORY_FIELDS:
                entry[f] = r.get(f, 0)
            records.append(entry)
    line = json.dumps({"run": label, "time": int(time.time()),
                       "records": records}, sort_keys=True)
    path.parent.mkdir(parents=True, exist_ok=True)
    lines: list[str] = []
    if path.is_file():
        for old in path.read_text().splitlines():
            old = old.strip()
            if not old:
                continue
            try:
                json.loads(old)
            except json.JSONDecodeError:
                continue  # drop corrupt lines instead of carrying them
            lines.append(old)
    lines.append(line)
    lines = lines[-max(max_runs, 1):]
    path.write_text("\n".join(lines) + "\n")
    return len(lines)


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("current", type=Path, help="fresh bench_results dir")
    ap.add_argument("baseline", type=Path, help="previous run's dir")
    ap.add_argument("--wall-warn-pct", type=float, default=25.0,
                    help="flag wall-time moves beyond this percentage")
    ap.add_argument("--history", type=Path, default=None,
                    help="rolling multi-run history file (JSON lines)")
    ap.add_argument("--history-max", type=int, default=50,
                    help="keep at most this many runs in --history")
    ap.add_argument("--run-label", default=None,
                    help="label of this run in the history "
                         "(default: $GITHUB_RUN_ID or 'local')")
    ap.add_argument("--schema", type=Path, default=SCHEMA_FILE,
                    help="schema_fields.json emitted by "
                         "`armincut analyze --emit-schema`")
    args = ap.parse_args(argv)

    if not args.current.is_dir():
        print(f"error: current dir {args.current} does not exist")
        return 2
    current = load_dir(args.current)
    if not current:
        print(f"error: no BENCH_*.json in {args.current}")
        return 2
    if args.schema.is_file():
        try:
            schema = json.loads(args.schema.read_text())
        except (OSError, json.JSONDecodeError) as e:
            print(f"error: unreadable schema {args.schema}: {e}")
            return 2
        problems = validate_records(current, schema)
        if problems:
            for p in problems:
                print(f"schema drift: {p}")
            print(f"\n{len(problems)} schema problem(s) in the current run")
            return 1
    else:
        print(f"warning: no record schema at {args.schema}, skipping validation")
    if args.history is not None:
        label = args.run_label or os.environ.get("GITHUB_RUN_ID", "local")
        runs = append_history(args.history, label, current, args.history_max)
        print(f"history: {runs} run(s) tracked in {args.history}")
    if not args.baseline.is_dir():
        print(f"no baseline at {args.baseline} (first run?) — nothing to diff")
        return 0
    baseline = load_dir(args.baseline)
    if not baseline:
        print(f"baseline {args.baseline} holds no BENCH_*.json — nothing to diff")
        return 0

    mismatches, compared = compare(current, baseline, args.wall_warn_pct)
    print(f"\ncompared {compared} records, {mismatches} flow mismatch(es)")
    return 1 if mismatches else 0


if __name__ == "__main__":
    sys.exit(main())
