# Convenience targets. `make artifacts` needs JAX (python/compile/aot.py);
# everything else is plain cargo/pytest.

.PHONY: artifacts build test bench-quick table2 pytest analyze

artifacts:
	cd python && python3 -m compile.aot --out ../artifacts/model.hlo.txt

build:
	cd rust && cargo build --release

test:
	cd rust && cargo test -q

bench-quick:
	cd rust && cargo run --release -- bench all --quick --out bench_results

# Reproduce the Table-2 competition incl. the D-ARD(1..8) distributed
# speedup curve. Quick tier by default; ARMINCUT_FULL=1 for
# paper-scale instances.
table2:
	cd rust && cargo run --release -- bench table2 --out bench_results

pytest:
	python3 -m pytest python/tests -q

# Repo-invariant static analysis (schema drift, protocol
# exhaustiveness, panic policy) — the same gate CI runs.
analyze:
	cd rust && cargo run --release -- analyze
