# Convenience targets. `make artifacts` needs JAX (python/compile/aot.py);
# everything else is plain cargo/pytest.

.PHONY: artifacts build test bench-quick pytest

artifacts:
	cd python && python3 -m compile.aot --out ../artifacts/model.hlo.txt

build:
	cd rust && cargo build --release

test:
	cd rust && cargo test -q

bench-quick:
	cd rust && cargo run --release -- bench all --quick --out bench_results

pytest:
	python3 -m pytest python/tests -q
